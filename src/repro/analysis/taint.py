"""Jit-region discovery and traced-value taint propagation (RPR1xx engine).

A *jit region* is a function whose arguments are JAX tracers when it runs:
a def decorated with ``jax.jit`` (directly or through
``functools.partial``), or a function/lambda passed into one of the
tracing combinators (``jax.jit``, ``jax.vmap``, ``lax.fori_loop``,
``lax.scan``, ``lax.while_loop``, ...).  Inside a region, the parameters
(minus ``static_argnums``/``static_argnames``) are *tainted*; taint flows
through assignments and arbitrary calls, and is killed by the things that
are static at trace time — ``.shape``/``.ndim``/``.dtype``/``.size``,
``len()``, ``isinstance()``, and ``is``/``is not`` comparisons (the
``x is None`` default-argument idiom is trace-safe).

The analysis is intraprocedural on purpose: a helper *called from* a
region is not analyzed as traced (its config params — ``cap``, ``block`` —
are legitimately branched on at trace time), so precision beats recall.
The whole-repo clean test keeps the false-positive rate at zero.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

from repro.analysis.core import ModuleContext

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

# combinator -> positional indices whose argument is traced when called
TRACE_WRAPPERS: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,),
    "jax.pmap": (0,),
    "jax.vmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.cond": (1, 2),
}

# attribute reads that are static at trace time (never carry taint)
STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

# calls whose result is static at trace time
STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "callable", "id"}

# Python casts that force a host sync / concretization on a tracer
HOST_CASTS = {"float", "int", "bool", "complex"}

# methods that force a device->host sync
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready",
                     "copy_to_host_async"}


@dataclasses.dataclass
class Region:
    """One traced function: its node, why it is traced, and which params
    are static (excluded from taint)."""

    node: FunctionNode
    reason: str                  # e.g. "@jax.jit" or "jax.lax.fori_loop arg"
    static_params: Set[str]

    def param_names(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return [n for n in names if n not in self.static_params]


def _static_params_from_call(call: ast.Call,
                             fn: Optional[FunctionNode]) -> Set[str]:
    """static_argnames / static_argnums of a jax.jit(...) call mapped to
    parameter names (best effort: literal str/int tuples only)."""
    out: Set[str] = set()
    pos_names: List[str] = []
    if fn is not None:
        a = fn.args
        pos_names = [p.arg for p in a.posonlyargs + a.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    if 0 <= n.value < len(pos_names):
                        out.add(pos_names[n.value])
    return out


def _local_def(ctx: ModuleContext, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _jit_decorator_regions(ctx: ModuleContext) -> Iterable[Region]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            # @jax.jit
            if ctx.resolves_to(dec, ("jax.jit", "jax.pmap")):
                yield Region(node, f"@{ctx.resolve(dec)}", set())
            elif isinstance(dec, ast.Call):
                # @functools.partial(jax.jit, static_argnames=...)
                if ctx.resolves_to(dec.func, ("functools.partial",)) \
                        and dec.args \
                        and ctx.resolves_to(dec.args[0],
                                            ("jax.jit", "jax.pmap")):
                    yield Region(node, f"@partial({ctx.resolve(dec.args[0])})",
                                 _static_params_from_call(dec, node))
                # @jax.jit(static_argnames=...)
                elif ctx.resolves_to(dec.func, ("jax.jit", "jax.pmap")):
                    yield Region(node, f"@{ctx.resolve(dec.func)}(...)",
                                 _static_params_from_call(dec, node))


def _wrapper_call_regions(ctx: ModuleContext) -> Iterable[Region]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = ctx.resolve(node.func)
        if target not in TRACE_WRAPPERS:
            continue
        for idx in TRACE_WRAPPERS[target]:
            if idx >= len(node.args):
                continue
            arg = node.args[idx]
            static = (_static_params_from_call(node, None)
                      if target == "jax.jit" else set())
            if isinstance(arg, ast.Lambda):
                yield Region(arg, f"{target} arg", static)
            elif isinstance(arg, ast.Name):
                fn = _local_def(ctx, arg.id)
                if fn is not None:
                    if target == "jax.jit":
                        static = _static_params_from_call(node, fn)
                    yield Region(fn, f"{target} arg", static)


def jit_regions(ctx: ModuleContext) -> List[Region]:
    """All jit regions of a module, deduplicated by function node."""
    seen: Set[int] = set()
    out: List[Region] = []
    for reg in list(_jit_decorator_regions(ctx)) \
            + list(_wrapper_call_regions(ctx)):
        if id(reg.node) not in seen:
            seen.add(id(reg.node))
            out.append(reg)
    return out


class TaintEngine:
    """Forward taint propagation over one region's body.

    Two passes: the first only propagates (so loop-carried taint settles),
    the second reports.  Nested function/class definitions are separate
    scopes and are skipped (they become their own regions if traced).
    """

    def __init__(self, ctx: ModuleContext, region: Region):
        self.ctx = ctx
        self.region = region
        self.tainted: Set[str] = set(region.param_names())

    # -- expression taint ----------------------------------------------------
    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            fname = self.ctx.resolve(node.func)
            if fname in STATIC_CALLS:
                return False
            parts = [a for a in node.args if not isinstance(a, ast.Starred)]
            parts += [a.value for a in node.args if isinstance(a, ast.Starred)]
            parts += [kw.value for kw in node.keywords]
            if isinstance(node.func, ast.Attribute):
                parts.append(node.func.value)   # method call on tainted obj
            return any(self.is_tainted(p) for p in parts)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not y` are identity checks on the Python
            # object (tracer vs None), static at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return any(self.is_tainted(c)
                       for c in [node.left] + list(node.comparators))
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            return False
        return any(self.is_tainted(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    # -- statement walk ------------------------------------------------------
    def _target_names(self, node: ast.AST) -> List[str]:
        out = []
        for n in ast.walk(node):
            if isinstance(n, ast.Name):
                out.append(n.id)
        return out

    def _propagate_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Assign):
            t = self.is_tainted(stmt.value)
            for target in stmt.targets:
                if t:
                    self.tainted.update(self._target_names(target))
                elif isinstance(target, ast.Name):
                    self.tainted.discard(target.id)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if self.is_tainted(stmt.value):
                self.tainted.update(self._target_names(stmt.target))
            elif isinstance(stmt.target, ast.Name):
                self.tainted.discard(stmt.target.id)
            return
        if isinstance(stmt, ast.AugAssign):
            if self.is_tainted(stmt.value):
                self.tainted.update(self._target_names(stmt.target))
            return
        if isinstance(stmt, ast.For):
            if self.is_tainted(stmt.iter):
                self.tainted.update(self._target_names(stmt.target))
        # walrus targets anywhere in the statement's expressions
        for n in ast.walk(stmt):
            if isinstance(n, ast.NamedExpr) and self.is_tainted(n.value):
                self.tainted.update(self._target_names(n.target))
        for body in _sub_bodies(stmt):
            for s in body:
                self._propagate_stmt(s)

    def propagate(self, passes: int = 2) -> None:
        body = self.region.node.body
        if isinstance(self.region.node, ast.Lambda):
            return                       # lambdas: expression only, no stmts
        for _ in range(passes):
            for stmt in body:
                self._propagate_stmt(stmt)


def _sub_bodies(stmt: ast.stmt) -> List[List[ast.stmt]]:
    out = []
    for attr in ("body", "orelse", "finalbody"):
        b = getattr(stmt, attr, None)
        if b and isinstance(b, list) \
                and all(isinstance(s, ast.stmt) for s in b):
            out.append(b)
    for h in getattr(stmt, "handlers", []) or []:
        out.append(h.body)
    return out


def region_statements(region: Region) -> Iterable[ast.stmt]:
    """Every statement in the region body, skipping nested defs/classes
    (they are separate scopes)."""
    if isinstance(region.node, ast.Lambda):
        return
    stack = list(region.node.body)
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield stmt
        for body in _sub_bodies(stmt):
            stack.extend(body)


def region_expressions(region: Region) -> Iterable[ast.expr]:
    """Every expression evaluated in the region body: the lambda body for
    lambda regions, each statement's own expressions otherwise."""
    if isinstance(region.node, ast.Lambda):
        yield region.node.body
        return
    for stmt in region_statements(region):
        yield from statement_expressions(stmt)


def statement_expressions(stmt: ast.stmt) -> Iterable[ast.expr]:
    """The statement's own expressions (not those of nested statements or
    nested function bodies)."""
    for field, value in ast.iter_fields(stmt):
        vals = value if isinstance(value, list) else [value]
        for v in vals:
            if isinstance(v, ast.expr):
                yield v


def walk_expr(e: ast.expr) -> Iterable[ast.expr]:
    """Walk an expression tree without descending into lambda bodies."""
    yield e
    if isinstance(e, ast.Lambda):
        return
    for c in ast.iter_child_nodes(e):
        if isinstance(c, ast.expr):
            yield from walk_expr(c)
        elif isinstance(c, (ast.comprehension,)):
            for sub in [c.iter, c.target] + list(c.ifs):
                yield from walk_expr(sub)
        elif isinstance(c, ast.keyword):
            yield from walk_expr(c.value)
