"""RPR2xx — Pallas kernel call-contract rules.

``pl.pallas_call`` failures are the worst kind: a block shape that does
not divide the output, or an index_map whose arity disagrees with the
grid, compiles fine under ``interpret=True`` on CPU and only explodes (or
silently reads garbage) on the Mosaic path.  These rules check the parts
of the contract that are statically visible at the call site.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from repro.analysis.core import Finding, ModuleContext, rule

PALLAS_CALL_NAMES = (
    "jax.experimental.pallas.pallas_call",
    "pallas.pallas_call",
    "pl.pallas_call",
)
SHAPE_STRUCT_NAMES = (
    "jax.ShapeDtypeStruct",
    "jax.core.ShapeDtypeStruct",
)
BLOCKSPEC_NAMES = (
    "jax.experimental.pallas.BlockSpec",
    "pallas.BlockSpec",
    "pl.BlockSpec",
)


def _is_pallas_call(ctx: ModuleContext, node: ast.Call) -> bool:
    name = ctx.resolve(node.func)
    return bool(name) and (name in PALLAS_CALL_NAMES
                           or name.endswith(".pallas_call"))


def _kw(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _literal_int_tuple(node: Optional[ast.expr]
                       ) -> Optional[Tuple[int, ...]]:
    """(1, 2, 3) as a tuple of ints, or None when any element is dynamic."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    out = []
    for e in node.elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            out.append(e.value)
        else:
            return None
    return tuple(out)


def _resolve_local_tuple(ctx: ModuleContext, node: Optional[ast.expr],
                         scope: ast.AST) -> Optional[ast.expr]:
    """Follow ``grid=grid`` one assignment back inside the enclosing
    function: the last ``grid = (<tuple>)`` before use wins."""
    if not isinstance(node, ast.Name):
        return node
    found: Optional[ast.expr] = None
    for n in ast.walk(scope):
        if isinstance(n, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == node.id
                        for t in n.targets) \
                and getattr(n, "lineno", 0) <= getattr(node, "lineno", 0):
            found = n.value
    return found


def _blockspecs(ctx: ModuleContext, node: Optional[ast.expr]
                ) -> List[ast.Call]:
    """All BlockSpec(...) constructor calls inside an in_specs/out_specs
    expression (a single spec, a list/tuple, or nested pytrees)."""
    if node is None:
        return []
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and ctx.resolves_to(n.func,
                                                       BLOCKSPEC_NAMES):
            out.append(n)
    return out


@rule("RPR201", "BlockSpec block shape does not divide the output shape")
def block_shape_divisibility(ctx: ModuleContext) -> Iterable[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(ctx, node)):
            continue
        shape_node = _kw(node, "out_shape")
        if isinstance(shape_node, ast.Call) \
                and ctx.resolves_to(shape_node.func, SHAPE_STRUCT_NAMES) \
                and shape_node.args:
            out_dims = _literal_int_tuple(shape_node.args[0])
        else:
            out_dims = None
        if out_dims is None:
            continue        # dynamic shapes: nothing statically checkable
        for spec in _blockspecs(ctx, _kw(node, "out_specs")):
            if not spec.args:
                continue
            block = _literal_int_tuple(spec.args[0])
            if block is None or len(block) != len(out_dims):
                continue
            bad = [d for d, (dim, blk) in enumerate(zip(out_dims, block))
                   if blk > 0 and dim % blk != 0]
            if bad:
                out.append(ctx.finding(
                    "RPR201", spec,
                    f"out_specs block shape {block} does not divide "
                    f"out_shape {out_dims} on axis(es) {bad}; Mosaic "
                    "requires whole blocks — pad the array or pick a "
                    "divisor block"))
    return out


@rule("RPR202", "BlockSpec index_map arity disagrees with the grid rank")
def index_map_arity(ctx: ModuleContext) -> Iterable[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(ctx, node)):
            continue
        scope = ctx.enclosing_function(node) or ctx.tree
        grid = _literal_int_tuple(
            _resolve_local_tuple(ctx, _kw(node, "grid"), scope))
        if grid is None:
            # shape unknown but rank may still be known: grid=(a, b)
            g = _resolve_local_tuple(ctx, _kw(node, "grid"), scope)
            if isinstance(g, (ast.Tuple, ast.List)):
                rank = len(g.elts)
            else:
                continue
        else:
            rank = len(grid)
        specs = (_blockspecs(ctx, _kw(node, "in_specs"))
                 + _blockspecs(ctx, _kw(node, "out_specs")))
        for spec in specs:
            imap = spec.args[1] if len(spec.args) > 1 \
                else _kw(spec, "index_map")
            if not isinstance(imap, ast.Lambda):
                continue
            a = imap.args
            n_params = len(a.posonlyargs) + len(a.args)
            if a.vararg is None and n_params != rank:
                out.append(ctx.finding(
                    "RPR202", imap,
                    f"index_map takes {n_params} grid indices but the "
                    f"grid has rank {rank}; every index_map must accept "
                    "one argument per grid axis"))
    return out


@rule("RPR203", "hardcoded interpret= flag bypasses the impl dispatch")
def hardcoded_interpret(ctx: ModuleContext) -> Iterable[Finding]:
    """Call sites must thread ``interpret`` from the ``impl='auto'``
    dispatch (``repro.kernels.ops``), never pin it: a literal
    ``interpret=True`` silently runs the emulator on TPU, a literal
    ``False`` breaks every CPU environment."""
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg == "interpret" \
                    and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, bool):
                out.append(ctx.finding(
                    "RPR203", kw.value,
                    f"interpret={kw.value.value} is hardcoded at the call "
                    "site; thread it from the impl='auto' dispatch "
                    "(repro.kernels.ops.resolve_impl) so CPU/TPU pick "
                    "the right path"))
    return out


@rule("RPR204", "pl.pallas_call used outside repro/kernels/")
def pallas_call_outside_kernels(ctx: ModuleContext) -> Iterable[Finding]:
    """All Pallas entry points live behind ``repro.kernels`` so the
    impl dispatch, padding and interpret threading happen exactly once."""
    if ctx.in_package_dir("repro/kernels/"):
        return []
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _is_pallas_call(ctx, node):
            out.append(ctx.finding(
                "RPR204", node,
                "direct pl.pallas_call outside repro/kernels/; wrap the "
                "kernel there and expose it through repro.kernels.ops "
                "so dispatch/padding stay centralized"))
    return out
