"""Analyzer core: findings, module context, rule registry, driver.

The analyzer is a plain ``ast`` pass (stdlib only — it must run in any CI
leg without installing jax) over the repo's own source.  Rules are
repo-specific: they encode the three contract surfaces whose breakage is
silent or runtime-only — jit trace-safety (RPR1xx), Pallas kernel call
contracts (RPR2xx), the fleet/artifact atomic-write discipline (RPR3xx)
and monotonic-clock timing discipline (RPR4xx).  See ``CONTRIBUTING.md``
for the rule catalog and how to add a rule.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str          # e.g. "RPR101"
    message: str       # human-readable, names the fix
    file: str          # path relative to the analysis root (posix sep)
    line: int
    col: int
    context: str       # enclosing function qualname, or "<module>"

    def key(self) -> Tuple[str, str, str]:
        """Baseline-matching identity: stable across unrelated edits
        (no line numbers)."""
        return (self.rule, self.file, self.context)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: {self.rule} "
                f"[{self.context}] {self.message}")


class ModuleContext:
    """Parsed module plus the name-resolution helpers every rule needs."""

    def __init__(self, path: str, source: str, relpath: str):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[int, ast.AST] = {}
        self._qualnames: Dict[int, str] = {}
        self.imports: Dict[str, str] = {}
        self._index()

    # -- construction --------------------------------------------------------
    def _index(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[id(child)] = node
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    # -- queries -------------------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted name of an expression through the module's import aliases:
        ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call``.
        None for anything that isn't a plain Name/Attribute chain."""
        if isinstance(node, ast.Name):
            return self.imports.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            return f"{base}.{node.attr}" if base else None
        return None

    def resolves_to(self, node: ast.AST, names: Sequence[str]) -> bool:
        return self.resolve(node) in set(names)

    def qualname(self, node: ast.AST) -> str:
        """Enclosing function qualname for a node ('<module>' at top level,
        'Outer.inner' for nested defs)."""
        if id(node) in self._qualnames:
            return self._qualnames[id(node)]
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            elif isinstance(cur, ast.Lambda):
                parts.append("<lambda>")
            cur = self.parents.get(id(cur))
        out = ".".join(reversed(parts)) or "<module>"
        self._qualnames[id(node)] = out
        return out

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(id(node))
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parents.get(id(cur))
        return None

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = self.parents.get(id(node))
        while cur is not None:
            yield cur
            cur = self.parents.get(id(cur))

    def in_package_dir(self, fragment: str) -> bool:
        """True when the module path contains ``fragment`` (posix form,
        e.g. 'repro/kernels/')."""
        return fragment in self.relpath

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, message=message, file=self.relpath,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       context=self.qualname(node))


RuleFn = Callable[[ModuleContext], Iterable[Finding]]


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    fn: RuleFn


_RULES: Dict[str, Rule] = {}


def rule(rule_id: str, title: str) -> Callable[[RuleFn], RuleFn]:
    """Register a rule function: ``fn(ctx) -> iterable of Finding``."""
    def deco(fn: RuleFn) -> RuleFn:
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        _RULES[rule_id] = Rule(rule_id, title, fn)
        return fn
    return deco


def all_rules() -> List[Rule]:
    _load_builtin_rules()
    return [_RULES[k] for k in sorted(_RULES)]


def _load_builtin_rules() -> None:
    # imported lazily so `import repro.analysis.core` alone never cycles
    from repro.analysis import rules_fleet  # noqa: F401
    from repro.analysis import rules_kernel  # noqa: F401
    from repro.analysis import rules_obs  # noqa: F401
    from repro.analysis import rules_trace  # noqa: F401


def iter_python_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in sorted(dirnames)
                           if not d.startswith(".") and d != "__pycache__"]
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return out


def analyze_file(path: str, root: Optional[str] = None,
                 rules: Optional[Sequence[Rule]] = None
                 ) -> List[Finding]:
    """Run (selected) rules over one file; syntax errors become a single
    RPR000 finding rather than an exception."""
    root = root or os.getcwd()
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    with open(path) as f:
        source = f.read()
    try:
        ctx = ModuleContext(path, source, rel)
    except SyntaxError as e:
        return [Finding("RPR000", f"syntax error: {e.msg}",
                        rel.replace(os.sep, "/"), e.lineno or 0,
                        e.offset or 0, "<module>")]
    findings: List[Finding] = []
    for r in (rules if rules is not None else all_rules()):
        findings.extend(r.fn(ctx))
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return findings


def analyze_paths(paths: Sequence[str], root: Optional[str] = None,
                  select: Optional[Sequence[str]] = None
                  ) -> Tuple[List[Finding], int]:
    """Analyze every ``.py`` under ``paths``; returns (findings, n_files).

    ``select`` filters rules by id prefix (``["RPR3"]`` runs only the fleet
    family); unknown prefixes raise ValueError.
    """
    rules = all_rules()
    if select:
        known = {r.id for r in rules}
        for s in select:
            if not any(k.startswith(s) for k in known):
                raise ValueError(
                    f"--select {s!r} matches no rule; have "
                    f"{', '.join(sorted(known))}")
        rules = [r for r in rules if any(r.id.startswith(s) for s in select)]
    files = iter_python_files(paths)
    findings: List[Finding] = []
    for f in files:
        findings.extend(analyze_file(f, root=root, rules=rules))
    return findings, len(files)
