"""``python -m repro.analysis`` — the repo's static-analysis gate.

Exit codes: 0 clean (or everything suppressed), 1 findings, 2 usage /
configuration error (unknown --select, malformed baseline).  ``--format
json`` emits a machine-readable report for tooling; CI runs the text
form with ``--baseline .analysis-baseline.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import all_rules, analyze_paths

DEFAULT_BASELINE = ".analysis-baseline.json"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analyzer: jit trace-safety "
                    "(RPR1xx), Pallas kernel contracts (RPR2xx), fleet "
                    "atomic-write discipline (RPR3xx)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="suppression file; entries need a non-empty "
                         f"reason (default: {DEFAULT_BASELINE} when it "
                         "exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any default baseline file")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="snapshot current findings as a baseline "
                         "skeleton (reasons seeded with a TODO) and exit")
    ap.add_argument("--select", action="append", default=None,
                    metavar="PREFIX",
                    help="run only rules matching this id prefix "
                         "(repeatable), e.g. --select RPR3")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--root", default=".",
                    help="path findings are reported relative to")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  {r.title}")
        return 0

    try:
        findings, n_files = analyze_paths(args.paths, root=args.root,
                                          select=args.select)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        n = baseline_mod.write_baseline(args.write_baseline, findings)
        print(f"wrote {args.write_baseline}: {n} entr"
              f"{'y' if n == 1 else 'ies'} (fill in the TODO reasons "
              "before committing)")
        return 0

    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline \
            and os.path.exists(os.path.join(args.root, DEFAULT_BASELINE)):
        baseline_path = os.path.join(args.root, DEFAULT_BASELINE)

    suppressed: List = []
    stale: List = []
    if baseline_path:
        try:
            bl = baseline_mod.load_baseline(baseline_path)
        except baseline_mod.BaselineError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        findings, suppressed, stale = baseline_mod.apply_baseline(
            findings, bl)

    if args.format == "json":
        print(json.dumps({
            "files": n_files,
            "findings": [f.to_dict() for f in findings],
            "suppressed": [f.to_dict() for f in suppressed],
            "stale_baseline_keys": [list(k) for k in stale],
        }, indent=1))
    else:
        for f in findings:
            print(f.render())
        for k in stale:
            print(f"warning: stale baseline entry {k[0]} {k[1]} [{k[2]}] "
                  "matches no finding — remove it", file=sys.stderr)
        tail = f"{n_files} file(s), {len(findings)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} suppressed by baseline"
        print(tail)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
