"""RPR3xx — fleet/artifact atomic-write discipline.

The fleet protocol (``repro.fleet.manifest``) survives worker crashes
because every published artifact is either O_EXCL-linked (claims) or
``os.replace``-d into place (shards, manifests, bench artifacts).  A
plain ``open(path, 'w')`` anywhere on those paths reintroduces the
torn-file window the protocol exists to close.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from repro.analysis.core import Finding, ModuleContext, rule

_WRITE_MODES = ("w", "w+", "wt", "w+t", "wb", "w+b")

TEMPFILE_MAKERS = (
    "tempfile.mkstemp",
    "tempfile.NamedTemporaryFile",
    "tempfile.TemporaryFile",
)


def _scope_of(ctx: ModuleContext, node: ast.AST) -> ast.AST:
    return ctx.enclosing_function(node) or ctx.tree


def _scope_calls(ctx: ModuleContext, scope: ast.AST,
                 names: Iterable[str]) -> bool:
    """Does the scope (not counting nested defs when scope is the module)
    call any of ``names``?"""
    target = set(names)
    for n in ast.walk(scope):
        if isinstance(n, ast.Call) and ctx.resolve(n.func) in target:
            return True
    return False


def _open_write_mode(node: ast.Call) -> Optional[str]:
    """The literal write mode of an ``open``/``.open`` call, else None."""
    mode: Optional[ast.expr] = None
    if len(node.args) > 1:
        mode = node.args[1]
    else:
        for kw in node.keywords:
            if kw.arg == "mode":
                mode = kw.value
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str) \
            and mode.value in _WRITE_MODES:
        return mode.value
    return None


@rule("RPR301", "plain truncating write bypasses the atomic-publish helpers")
def raw_truncating_write(ctx: ModuleContext) -> Iterable[Finding]:
    """``open(path, 'w')`` / ``Path.write_text`` truncate in place: a
    reader (or a crash) mid-write sees an empty/torn file.  Publish
    through ``repro.utils.atomicio`` instead.  A function that itself
    finishes with ``os.replace``/``os.link`` IS an atomic publisher — its
    internal tmp-file write is the implementation, not a violation."""
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        is_open = (ctx.resolve(node.func) in ("open", "io.open", "os.fdopen")
                   and _open_write_mode(node) is not None)
        is_write_text = (isinstance(node.func, ast.Attribute)
                         and node.func.attr in ("write_text", "write_bytes"))
        if not (is_open or is_write_text):
            continue
        scope = _scope_of(ctx, node)
        if _scope_calls(ctx, scope, ("os.replace", "os.rename", "os.link")):
            continue
        what = "open(..., 'w')" if is_open else f".{node.func.attr}(...)"
        out.append(ctx.finding(
            "RPR301", node,
            f"{what} truncates the target in place (torn file on crash, "
            "partial read for concurrent readers); publish via "
            "repro.utils.atomicio.atomic_write_text/_json"))
    return out


@rule("RPR302", "tempfile without dir= feeding an os.replace")
def cross_filesystem_replace(ctx: ModuleContext) -> Iterable[Finding]:
    """``tempfile.mkstemp()`` defaults to ``/tmp`` — usually a different
    filesystem from the artifact directory, where ``os.replace`` stops
    being atomic (EXDEV, or a copy+delete fallback).  Any tempfile that
    feeds a replace/rename must pin ``dir=`` next to the destination."""
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and ctx.resolve(node.func) in TEMPFILE_MAKERS):
            continue
        if any(kw.arg == "dir" for kw in node.keywords):
            continue
        scope = _scope_of(ctx, node)
        if _scope_calls(ctx, scope, ("os.replace", "os.rename")):
            out.append(ctx.finding(
                "RPR302", node,
                f"{ctx.resolve(node.func)}() without dir= defaults to "
                "/tmp, then the os.replace in this function crosses "
                "filesystems and loses atomicity; pass "
                "dir=os.path.dirname(dest) (or use "
                "repro.utils.atomicio, which writes a sibling tmp)"))
    return out


_CLAIM_MARKERS = (".claim",)


def _mentions_claim(fn: ast.AST) -> bool:
    name = getattr(fn, "name", "")
    if "claim" in name.lower():
        return True
    for n in ast.walk(fn):
        if isinstance(n, ast.Constant) and isinstance(n.value, str) \
                and any(m in n.value for m in _CLAIM_MARKERS):
            return True
    return False


def _has_excl_discipline(ctx: ModuleContext, fn: ast.AST) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            name = ctx.resolve(n.func)
            if name == "os.link":
                return True
            if name in ("open", "io.open"):
                mode = None
                if len(n.args) > 1:
                    mode = n.args[1]
                else:
                    for kw in n.keywords:
                        if kw.arg == "mode":
                            mode = kw.value
                if isinstance(mode, ast.Constant) \
                        and isinstance(mode.value, str) \
                        and "x" in mode.value:
                    return True
        if isinstance(n, ast.Attribute) and n.attr == "O_EXCL":
            return True
    return False


@rule("RPR303", "claim-file creation without O_EXCL semantics")
def claim_without_excl(ctx: ModuleContext) -> Iterable[Finding]:
    """Claims are mutual-exclusion tokens: two workers racing a plain
    ``open(claim_path, 'w')`` both think they won.  Creation must be
    atomic-exclusive — ``os.link`` of a prewritten tmp, ``os.open`` with
    ``O_CREAT|O_EXCL``, or open mode ``'x'``."""
    out: List[Finding] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _mentions_claim(fn):
            continue
        creates = [n for n in ast.walk(fn)
                   if isinstance(n, ast.Call)
                   and ctx.resolve(n.func) in ("open", "io.open")
                   and _open_write_mode(n) is not None]
        if creates and not _has_excl_discipline(ctx, fn):
            out.append(ctx.finding(
                "RPR303", creates[0],
                f"`{getattr(fn, 'name', '?')}` creates a claim file with a "
                "plain truncating open: two racing workers both succeed. "
                "Use os.link of a tmp file, os.open(..., "
                "O_CREAT|O_EXCL) or open(mode='x')"))
    return out
