"""RPR1xx — jit trace-safety rules.

These guard the single-XLA-program property of the compiled search path
(``core/nsga2_jax.py``, ``core/partition_jax.py``): one stray Python
branch on a tracer or one host sync inside a jitted region silently
splits the program (or raises ``TracerBoolConversionError`` only at run
time), undoing the PR-3 speedup.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.core import Finding, ModuleContext, rule
from repro.analysis.taint import (HOST_CASTS, HOST_SYNC_METHODS, TaintEngine,
                                  jit_regions, region_expressions,
                                  region_statements, walk_expr)

LARGE_BUFFER_PARAMS = {"X0", "X0s", "state", "population"}


@rule("RPR101", "Python control flow on a traced value inside a jit region")
def python_branch_on_tracer(ctx: ModuleContext) -> Iterable[Finding]:
    out: List[Finding] = []
    for region in jit_regions(ctx):
        eng = TaintEngine(ctx, region)
        eng.propagate()
        for stmt in region_statements(region):
            if isinstance(stmt, ast.If) and eng.is_tainted(stmt.test):
                out.append(ctx.finding(
                    "RPR101", stmt,
                    "Python `if` on a traced value inside a jit region "
                    f"({region.reason}); use jnp.where/lax.cond"))
            elif isinstance(stmt, ast.While) and eng.is_tainted(stmt.test):
                out.append(ctx.finding(
                    "RPR101", stmt,
                    "Python `while` on a traced value inside a jit region "
                    f"({region.reason}); use lax.while_loop"))
            elif isinstance(stmt, ast.For) and eng.is_tainted(stmt.iter):
                out.append(ctx.finding(
                    "RPR101", stmt,
                    "Python `for` over a traced value inside a jit region "
                    f"({region.reason}); use lax.fori_loop/lax.scan"))
            elif isinstance(stmt, ast.Assert) and eng.is_tainted(stmt.test):
                out.append(ctx.finding(
                    "RPR101", stmt,
                    "`assert` on a traced value inside a jit region "
                    f"({region.reason}); use checkify or move the check "
                    "outside the jit"))
        for e in region_expressions(region):
            for sub in walk_expr(e):
                if isinstance(sub, ast.IfExp) and eng.is_tainted(sub.test):
                    out.append(ctx.finding(
                        "RPR101", sub,
                        "conditional expression on a traced value inside "
                        f"a jit region ({region.reason}); use jnp.where"))
    return out


@rule("RPR102", "host sync on a device value inside a jit region")
def host_sync_in_jit(ctx: ModuleContext) -> Iterable[Finding]:
    out: List[Finding] = []
    for region in jit_regions(ctx):
        eng = TaintEngine(ctx, region)
        eng.propagate()
        for e in region_expressions(region):
            for sub in walk_expr(e):
                if not isinstance(sub, ast.Call):
                    continue
                fname = ctx.resolve(sub.func)
                args_tainted = any(eng.is_tainted(a) for a in sub.args)
                if fname in HOST_CASTS and args_tainted:
                    out.append(ctx.finding(
                        "RPR102", sub,
                        f"`{fname}()` on a traced value inside a jit "
                        f"region ({region.reason}) forces a host sync "
                        "and breaks the trace; keep it as a jnp array"))
                elif fname and fname.startswith("numpy.") \
                        and args_tainted:
                    out.append(ctx.finding(
                        "RPR102", sub,
                        f"`{fname}` on a traced value inside a jit "
                        f"region ({region.reason}) pulls the buffer to "
                        "host; use jax.numpy instead"))
                elif isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr in HOST_SYNC_METHODS \
                        and eng.is_tainted(sub.func.value):
                    out.append(ctx.finding(
                        "RPR102", sub,
                        f"`.{sub.func.attr}()` on a traced value inside "
                        f"a jit region ({region.reason}) forces a "
                        "device->host sync"))
    return out


@rule("RPR103", "jax.jit constructed inside a loop (no compilation cache)")
def jit_in_loop(ctx: ModuleContext) -> Iterable[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and ctx.resolves_to(node.func, ("jax.jit", "jax.pmap"))):
            continue
        for anc in ctx.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                break               # loops outside the def don't re-run it
            if isinstance(anc, (ast.For, ast.While)):
                out.append(ctx.finding(
                    "RPR103", node,
                    "jax.jit(...) constructed inside a loop recompiles "
                    "every iteration; hoist it (or cache the jitted "
                    "callable) outside the loop"))
                break
    return out


@rule("RPR104", "large-buffer runner jitted without donate_argnums")
def missing_donation(ctx: ModuleContext) -> Iterable[Finding]:
    """Entry points that thread a population/state buffer through a jitted
    runner must donate it (``donate_argnums``) or every call holds two
    copies of the largest array in the program (the PR-4 pop-32768 RSS
    win depends on this)."""
    from repro.analysis.taint import _local_def
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and ctx.resolves_to(node.func, ("jax.jit",))):
            continue
        if any(kw.arg in ("donate_argnums", "donate_argnames")
               for kw in node.keywords):
            continue
        if not node.args:
            continue
        target = node.args[0]
        fn = None
        if isinstance(target, ast.Name):
            fn = _local_def(ctx, target.id)
        elif isinstance(target, ast.Lambda):
            fn = target
        if fn is None:
            continue
        a = fn.args
        params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        hit = sorted(params & LARGE_BUFFER_PARAMS)
        if hit:
            out.append(ctx.finding(
                "RPR104", node,
                f"jit of a runner taking large buffer(s) {hit} without "
                "donate_argnums/donate_argnames; the caller's copy stays "
                "live for the whole run — donate it"))
    # decorator form: @jax.jit on a def with a large-buffer param
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in fn.decorator_list:
            is_plain = ctx.resolves_to(dec, ("jax.jit",))
            is_call = (isinstance(dec, ast.Call)
                       and (ctx.resolves_to(dec.func, ("jax.jit",))
                            or (ctx.resolves_to(dec.func,
                                                ("functools.partial",))
                                and dec.args
                                and ctx.resolves_to(dec.args[0],
                                                    ("jax.jit",)))))
            if not (is_plain or is_call):
                continue
            if is_call and any(kw.arg in ("donate_argnums",
                                          "donate_argnames")
                               for kw in dec.keywords):
                continue
            a = fn.args
            params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
            hit = sorted(params & LARGE_BUFFER_PARAMS)
            if hit:
                out.append(ctx.finding(
                    "RPR104", dec,
                    f"jitted `{fn.name}` takes large buffer(s) {hit} "
                    "without donate_argnums/donate_argnames; donate the "
                    "buffer so it can be reused in place"))
    return out
