"""Repo-specific static analyzer (stdlib ``ast`` only, no jax import).

Three rule families, one per contract surface whose breakage is silent
or runtime-only:

* **RPR1xx trace-safety** — Python control flow / host syncs on traced
  values inside jit regions, jit-in-loop, missing buffer donation.
* **RPR2xx Pallas kernel contracts** — block/grid divisibility,
  index_map arity, hardcoded ``interpret=`` flags, ``pallas_call``
  outside ``repro/kernels/``.
* **RPR3xx fleet atomicity** — truncating writes bypassing
  ``repro.utils.atomicio``, cross-filesystem tmp+replace, claim files
  without O_EXCL semantics.

CLI: ``python -m repro.analysis [paths...] [--baseline FILE]``.
"""

from repro.analysis.baseline import (Baseline, BaselineError,
                                     apply_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.core import (Finding, ModuleContext, Rule, all_rules,
                                 analyze_file, analyze_paths, rule)

__all__ = [
    "Baseline", "BaselineError", "Finding", "ModuleContext", "Rule",
    "all_rules", "analyze_file", "analyze_paths", "apply_baseline",
    "load_baseline", "rule", "write_baseline",
]
