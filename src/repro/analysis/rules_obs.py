"""RPR4xx — monotonic-clock discipline for timing code.

The serve runtime, the fleet protocol and the observability layer all
measure durations (stage occupancy, TTFT, lease heartbeats, span walls).
``time.time()`` and wall-clock ``datetime`` are the wrong instruments for
that: NTP slew, DST shifts and manual clock changes make their differences
jump backwards or by hours, which silently corrupts latency percentiles,
health EWMAs and trace spans.  Inside ``repro/serve/``, ``repro/fleet/``
and ``repro/obs/`` every elapsed-time measurement must use
``time.perf_counter()`` (or ``time.monotonic()``).

Comparing against an *epoch-stamped external fact* (e.g. a file mtime in
``Manifest.reclaim_stale``) genuinely needs ``time.time()`` — such sites
are acknowledged in the analysis baseline, not rewritten.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.core import Finding, ModuleContext, rule

# directories under the monotonic-clock contract
_SCOPED_DIRS = ("repro/serve/", "repro/fleet/", "repro/obs/")

_TIME_CLOCKS = ("time.time",)
_DATETIME_CLOCKS = (
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "datetime.date.today",
)


def _in_scope(ctx: ModuleContext) -> bool:
    return any(ctx.in_package_dir(d) for d in _SCOPED_DIRS)


def _scope_id(ctx: ModuleContext, node: ast.AST) -> int:
    return id(ctx.enclosing_function(node) or ctx.tree)


def _tainted_names(ctx: ModuleContext, clocks: Sequence[str]
                   ) -> Dict[Tuple[int, str], str]:
    """Names assigned straight from a wall-clock call, keyed by their
    enclosing scope — ``t0 = time.time()`` taints ``t0`` for later
    subtraction checks within the same function (or module body)."""
    clock_set = set(clocks)
    out: Dict[Tuple[int, str], str] = {}
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        resolved = ctx.resolve(node.value.func)
        if resolved not in clock_set:
            continue
        scope = _scope_id(ctx, node)
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                out[(scope, tgt.id)] = resolved
    return out


def _duration_findings(ctx: ModuleContext, rule_id: str,
                       clocks: Sequence[str], advice: str
                       ) -> Iterable[Finding]:
    """Flag subtractions where an operand is a wall-clock read — directly
    (``time.time() - t0``) or through a name assigned from one."""
    if not _in_scope(ctx):
        return []
    clock_set = set(clocks)
    tainted = _tainted_names(ctx, clocks)
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Sub)):
            continue
        culprit: Optional[str] = None
        for operand in (node.left, node.right):
            if isinstance(operand, ast.Call):
                resolved = ctx.resolve(operand.func)
                if resolved in clock_set:
                    culprit = f"{resolved}()"
                    break
            elif isinstance(operand, ast.Name):
                key = (_scope_id(ctx, node), operand.id)
                if key in tainted:
                    culprit = f"{operand.id} (assigned from {tainted[key]}())"
                    break
        if culprit is not None:
            out.append(ctx.finding(
                rule_id, node,
                f"duration measured by subtracting {culprit}: wall clocks "
                f"jump under NTP slew/DST and corrupt the elapsed value; "
                f"{advice}"))
    return out


@rule("RPR401", "time.time() subtraction measures a duration on a wall clock")
def walltime_duration(ctx: ModuleContext) -> Iterable[Finding]:
    """``time.time() - t0`` (or a name assigned from ``time.time()`` used
    in a subtraction) inside ``repro/serve``, ``repro/fleet`` or
    ``repro/obs`` — elapsed time there must come from
    ``time.perf_counter()`` / ``time.monotonic()``.  A subtraction against
    an epoch-stamped external fact (file mtime, message timestamp) is the
    one legitimate use; acknowledge it in the analysis baseline."""
    return _duration_findings(
        ctx, "RPR401", _TIME_CLOCKS,
        "use time.perf_counter() (or time.monotonic()) for both endpoints")


@rule("RPR402", "datetime arithmetic measures a duration on a wall clock")
def datetime_duration(ctx: ModuleContext) -> Iterable[Finding]:
    """``datetime.now() - started`` style arithmetic in the scoped runtime
    dirs: same wall-clock hazard as RPR401 with extra timezone/DST failure
    modes.  Durations come from ``time.perf_counter()``; ``datetime`` is
    for formatting moments, not measuring intervals."""
    return _duration_findings(
        ctx, "RPR402", _DATETIME_CLOCKS,
        "take time.perf_counter() at both endpoints and subtract those")
