"""Baseline suppression file for the analyzer.

A baseline entry acknowledges one finding — keyed ``(rule, file,
context)``, deliberately without line numbers so unrelated edits to the
same file do not invalidate it — and MUST carry a non-empty ``reason``.
An empty reason is a configuration error (exit 2): the whole point of
the file is that every suppression is a written-down justification a
reviewer can challenge.

Format (``.analysis-baseline.json`` at the repo root)::

    {
      "baseline_schema": 1,
      "entries": [
        {"rule": "RPR301", "file": "src/x.py", "context": "f",
         "reason": "scratch file private to this process"}
      ]
    }

Stale entries (matching no current finding) are reported as warnings so
the file shrinks as violations get fixed, but they never fail the run.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.core import Finding

BASELINE_SCHEMA = 1

Key = Tuple[str, str, str]


class BaselineError(Exception):
    """Malformed baseline file — exit code 2, not a finding."""


@dataclasses.dataclass
class Baseline:
    entries: List[Dict[str, str]]
    path: str

    def keys(self) -> Set[Key]:
        return {(e["rule"], e["file"], e["context"]) for e in self.entries}

    def reason_for(self, key: Key) -> str:
        for e in self.entries:
            if (e["rule"], e["file"], e["context"]) == key:
                return e["reason"]
        return ""


def load_baseline(path: str) -> Baseline:
    try:
        with open(path) as f:
            raw = json.load(f)
    except OSError as e:
        raise BaselineError(f"cannot read baseline {path}: {e}")
    except json.JSONDecodeError as e:
        raise BaselineError(f"baseline {path} is not valid JSON: {e}")
    if not isinstance(raw, dict) \
            or raw.get("baseline_schema") != BASELINE_SCHEMA:
        raise BaselineError(
            f"baseline {path}: expected baseline_schema="
            f"{BASELINE_SCHEMA}, got {raw.get('baseline_schema')!r}")
    entries = raw.get("entries")
    if not isinstance(entries, list):
        raise BaselineError(f"baseline {path}: 'entries' must be a list")
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise BaselineError(f"baseline {path}: entry {i} is not an "
                                "object")
        for field in ("rule", "file", "context", "reason"):
            if not isinstance(e.get(field), str):
                raise BaselineError(
                    f"baseline {path}: entry {i} missing string field "
                    f"{field!r}")
        if not e["reason"].strip():
            raise BaselineError(
                f"baseline {path}: entry {i} ({e['rule']} {e['file']} "
                f"[{e['context']}]) has an empty reason — every "
                "suppression needs a written justification")
    return Baseline(entries=entries, path=path)


def apply_baseline(findings: Sequence[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding], List[Key]]:
    """Split findings into (kept, suppressed); third element lists stale
    baseline keys that matched nothing."""
    keys = baseline.keys()
    kept = [f for f in findings if f.key() not in keys]
    suppressed = [f for f in findings if f.key() in keys]
    live = {f.key() for f in findings}
    stale = sorted(k for k in keys if k not in live)
    return kept, suppressed, stale


def write_baseline(path: str, findings: Sequence[Finding],
                   reason: str = "TODO: justify this suppression") -> int:
    """Snapshot current findings into a baseline skeleton.  Reasons are
    seeded with a TODO the loader will accept (non-empty) but reviewers
    should replace; one entry per unique key."""
    seen: Set[Key] = set()
    entries = []
    for f in findings:
        if f.key() in seen:
            continue
        seen.add(f.key())
        entries.append({"rule": f.rule, "file": f.file,
                        "context": f.context, "reason": reason})
    payload = {"baseline_schema": BASELINE_SCHEMA, "entries": entries}
    from repro.utils.atomicio import atomic_write_json
    atomic_write_json(path, payload)
    return len(entries)
