"""Optimizers (optax is unavailable offline): AdamW, Adafactor, SGD.

API mirrors optax: ``opt.init(params) -> opt_state``;
``opt.update(grads, opt_state, params) -> (updates, opt_state)``;
``apply_updates(params, updates)``.

Adafactor (factored second moments, no first moment by default) exists for
the ≥70B configs where full Adam states don't fit HBM (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _to_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def sgd(lr, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g,
                                    state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: momentum * m + g, mu,
                                         grads)
        else:
            upd = mu
        lr_t = sched(step)
        upd = jax.tree_util.tree_map(lambda u: -lr_t * u, upd)
        return upd, {"mu": mu, "step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = sched(step)

        def u(m_, v_, p):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p.ndim >= 2:       # no decay on norms/bias
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (-lr_t * upd).astype(jnp.float32)

        updates = jax.tree_util.tree_map(u, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adafactor(lr, min_dim_size_to_factor: int = 128,
              decay_rate: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018)."""
    sched = _to_schedule(lr)

    def _factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor
                and p.shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"slots": jax.tree_util.tree_map(one, params,
                                                is_leaf=lambda x: hasattr(x, "ndim")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay_rate)
        lr_t = sched(step)

        def one(g, slot, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in slot:
                vr = beta * slot["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * slot["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                pre = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                upd = g32 * jax.lax.rsqrt(pre + eps)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                upd = g32 * jax.lax.rsqrt(v + eps)
                new_slot = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return -lr_t * upd, new_slot

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        flat_p = tdef.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        slots = tdef.unflatten([o[1] for o in outs])
        return updates, {"slots": slots, "step": step}

    return Optimizer(init, update)


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
