"""CLI for the generated API reference and the docstring-coverage gate.

  PYTHONPATH=src python -m repro.docs                 # rewrite docs/api.md
  PYTHONPATH=src python -m repro.docs --check         # CI docstring gate
  PYTHONPATH=src python -m repro.docs --out other.md  # custom target
"""

from __future__ import annotations

import argparse
import sys

from repro.docs import missing_docstrings, render_api_md
from repro.utils.atomicio import atomic_write_text


def main() -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.docs")
    ap.add_argument("--check", action="store_true",
                    help="verify docstring coverage of PUBLIC_API and that "
                         "the reference renders; write nothing")
    ap.add_argument("--out", default="docs/api.md",
                    help="markdown target (default docs/api.md)")
    args = ap.parse_args()

    missing = missing_docstrings()
    md = render_api_md()            # also a smoke test: every entry imports
    if missing:
        print(f"docstring coverage: {len(missing)} public object(s) "
              "undocumented:", file=sys.stderr)
        for path in missing:
            print(f"  {path}", file=sys.stderr)
        return 1
    if args.check:
        n = sum(len(names) for _, names in
                __import__("repro.docs", fromlist=["PUBLIC_API"]).PUBLIC_API)
        print(f"docstring coverage: ok ({n} public objects, "
              f"{len(md.splitlines())} rendered lines)")
        return 0
    atomic_write_text(args.out, md)
    print(f"wrote {args.out} ({len(md.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
