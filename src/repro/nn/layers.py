"""Basic layers: Dense, Conv2d (NCHW), norms, pools, embeddings, SE block."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, kaiming, normal_init


class Dense(Module):
    def __init__(self, d_in: int, d_out: int, bias: bool = True,
                 init_std: Optional[float] = None, dtype=jnp.float32):
        self.d_in, self.d_out, self.bias = d_in, d_out, bias
        self.init_std = init_std
        self.dtype = dtype

    def init(self, key):
        if self.init_std is None:
            w = kaiming(key, (self.d_in, self.d_out), fan_in=self.d_in,
                        dtype=self.dtype)
        else:
            w = normal_init(key, (self.d_in, self.d_out), self.init_std,
                            dtype=self.dtype)
        p = {"w": w}
        if self.bias:
            p["b"] = jnp.zeros((self.d_out,), self.dtype)
        return p, {}

    def apply(self, params, state, x, **kw):
        y = x @ params["w"]
        if self.bias:
            y = y + params["b"]
        return y, state


class Conv2d(Module):
    """NCHW conv; weights (cout, cin/groups, kh, kw)."""

    def __init__(self, cin: int, cout: int, kernel: int, stride: int = 1,
                 padding: Optional[int] = None, groups: int = 1,
                 bias: bool = True):
        self.cin, self.cout, self.k = cin, cout, kernel
        self.stride, self.groups, self.bias = stride, groups, bias
        self.padding = kernel // 2 if padding is None else padding

    def init(self, key):
        shape = (self.cout, self.cin // self.groups, self.k, self.k)
        p = {"w": kaiming(key, shape,
                          fan_in=(self.cin // self.groups) * self.k * self.k)}
        if self.bias:
            p["b"] = jnp.zeros((self.cout,))
        return p, {}

    def apply(self, params, state, x, **kw):
        y = jax.lax.conv_general_dilated(
            x, params["w"], window_strides=(self.stride, self.stride),
            padding=[(self.padding, self.padding)] * 2,
            feature_group_count=self.groups,
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if self.bias:
            y = y + params["b"][None, :, None, None]
        return y, state


class BatchNorm2d(Module):
    """NCHW batch norm with running stats in ``state``."""

    def __init__(self, c: int, momentum: float = 0.9, eps: float = 1e-5):
        self.c, self.momentum, self.eps = c, momentum, eps

    def init(self, key):
        p = {"scale": jnp.ones((self.c,)), "bias": jnp.zeros((self.c,))}
        s = {"mean": jnp.zeros((self.c,)), "var": jnp.ones((self.c,))}
        return p, s

    def apply(self, params, state, x, train: bool = False, **kw):
        if train:
            mean = x.mean(axis=(0, 2, 3))
            var = x.var(axis=(0, 2, 3))
            m = self.momentum
            new_state = {"mean": m * state["mean"] + (1 - m) * mean,
                         "var": m * state["var"] + (1 - m) * var}
        else:
            mean, var = state["mean"], state["var"]
            new_state = state
        inv = jax.lax.rsqrt(var + self.eps)
        y = (x - mean[None, :, None, None]) * inv[None, :, None, None]
        y = y * params["scale"][None, :, None, None] + params["bias"][None, :, None, None]
        return y, new_state


class LayerNorm(Module):
    def __init__(self, d: int, eps: float = 1e-5, bias: bool = True):
        self.d, self.eps, self.bias = d, eps, bias

    def init(self, key):
        p = {"scale": jnp.ones((self.d,))}
        if self.bias:
            p["bias"] = jnp.zeros((self.d,))
        return p, {}

    def apply(self, params, state, x, **kw):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + self.eps) * params["scale"]
        if self.bias:
            y = y + params["bias"]
        return y, state


class RMSNorm(Module):
    def __init__(self, d: int, eps: float = 1e-6):
        self.d, self.eps = d, eps

    def init(self, key):
        return {"scale": jnp.ones((self.d,))}, {}

    def apply(self, params, state, x, **kw):
        return rms_norm(x, params["scale"], self.eps), state


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


class Embedding(Module):
    def __init__(self, vocab: int, d: int, std: float = 0.02):
        self.vocab, self.d, self.std = vocab, d, std

    def init(self, key):
        return {"table": normal_init(key, (self.vocab, self.d), self.std)}, {}

    def apply(self, params, state, ids, **kw):
        return jnp.take(params["table"], ids, axis=0), state


def max_pool(x, kernel: int, stride: Optional[int] = None, padding: int = 0):
    stride = stride or kernel
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, kernel, kernel),
        (1, 1, stride, stride), [(0, 0), (0, 0)] + [(padding, padding)] * 2)


def avg_pool(x, kernel: int, stride: Optional[int] = None, padding: int = 0):
    stride = stride or kernel
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, kernel, kernel),
        (1, 1, stride, stride), [(0, 0), (0, 0)] + [(padding, padding)] * 2)
    return s / (kernel * kernel)


def global_avg_pool(x):
    return x.mean(axis=(2, 3))


class SqueezeExcite(Module):
    def __init__(self, c: int, reduced: int):
        self.c, self.reduced = c, reduced
        self.fc1 = Dense(c, reduced)
        self.fc2 = Dense(reduced, c)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"fc1": self.fc1.init(k1)[0], "fc2": self.fc2.init(k2)[0]}, {}

    def apply(self, params, state, x, **kw):
        s = global_avg_pool(x)
        s, _ = self.fc1.apply(params["fc1"], {}, s)
        s = jax.nn.silu(s)
        s, _ = self.fc2.apply(params["fc2"], {}, s)
        s = jax.nn.sigmoid(s)
        return x * s[:, :, None, None], state


# activation modules ---------------------------------------------------------

def act_module(name: str):
    from repro.nn.module import Lambda
    fns = {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu,
           "sigmoid": jax.nn.sigmoid, "swish": jax.nn.silu,
           "identity": lambda x: x}
    return Lambda(fns[name], name)
