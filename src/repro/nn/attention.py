"""Attention machinery: RoPE / M-RoPE, GQA, qk-norm, sliding windows,
KV caches (full + ring-buffer window), and DeepSeek-V3 MLA.

Shapes: activations (B, T, D); caches (B, n_kv, S, hd) — S is the cache
capacity (full seq or sliding window).  Decode is T=1 against a cache.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import rms_norm
from repro.nn.module import Module, normal_init

Cache = Dict[str, jnp.ndarray]


# -- rotary embeddings --------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, T, H, hd); positions: (B, T) integer positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Tuple[int, ...], theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions3: (3, B, T) — temporal/height/width position ids.
    sections: per-axis frequency-band sizes (in half-dims), sum = hd/2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3,B,T,hd/2)
    parts = []
    start = 0
    for axis, sec in enumerate(sections):
        parts.append(ang_all[axis, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)               # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# -- masking ------------------------------------------------------------------

def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: Optional[int] = None) -> jnp.ndarray:
    """(..., Tq, Tk) boolean mask: True = attend."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def sdpa(q, k, v, mask, impl: str = "ref") -> jnp.ndarray:
    """q: (B,T,H,hd), k: (B,S,Kv,hd), v: (B,S,Kv,vd), mask: (B,T,S)/(T,S)."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    group = h // kv
    qg = q.reshape(b, t, kv, group, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return out.reshape(b, t, h, vd)


def chunked_sdpa(q, k, v, window: Optional[int] = None,
                 chunk_q: int = 512) -> jnp.ndarray:
    """Memory-bounded causal attention: lax.scan over query chunks.

    Never materializes the (T, T) score matrix — per step it is
    (chunk_q, S), so 32k-token prefill lowers with O(T·chunk) intermediates
    (flash-attention shape without a custom kernel; the Pallas kernel covers
    the windowed case on TPU).  q: (B,T,H,hd); k/v: (B,S,Kv,hd-like).

    §Perf opt "attn_kv": when the rules map 'attn_kv' to a mesh axis, the kv
    head dimension is sharded (the caller duplicated kv heads if needed) and
    k/v carry FULL sequence — the Megatron pattern (gather once per layer,
    compute head-parallel) instead of per-chunk gathers of seq-sharded k/v.
    """
    from repro.nn.sharding import axis_size, shard
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    group = h // kv
    if t % chunk_q:
        chunk_q = t  # fallback: single chunk
    nq = t // chunk_q
    head_shard = axis_size("attn_kv") > 1 and kv % axis_size("attn_kv") == 0
    if head_shard:
        k = shard(k, ("batch", None, "attn_kv", None))
        v = shard(v, ("batch", None, "attn_kv", None))
    qc = q.reshape(b, nq, chunk_q, kv, group, hd)
    qc = jnp.moveaxis(qc, 1, 0)                       # (nq,b,cq,kv,g,hd)
    if head_shard:
        qc = shard(qc, (None, "batch", None, "attn_kv", None, None))
    k_pos = jnp.arange(s)
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)

    def step(qi, outs):
        # fori_loop + in-place DUS (aliased carry) instead of lax.scan:
        # scan's stacked xs/ys loop-state copies dominated HBM traffic
        # (§Perf hillclimb C2 — confirmed ~4 TB/step of copies on
        # musicgen prefill before this change)
        q_blk = jax.lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
        q_pos = qi * chunk_q + jnp.arange(chunk_q)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        # single (b,kv,g,cq,s) layout end-to-end: einsum outputs, mask,
        # softmax and the PV product all share it, so XLA emits no per-chunk
        # f32 transpose copies (§Perf hillclimb C3 — they were ~4 TB/step)
        sc = jnp.einsum("bckgh,bskh->bkgcs", q_blk, k) * scale
        if head_shard:
            sc = shard(sc, ("batch", "attn_kv", None, None, None))
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        # §Perf "softmax_low": keep the softmax in the compute dtype — the
        # f32 score materialization is the last big HBM term; the Pallas
        # kernel path keeps scores in VMEM at f32 regardless.
        from repro.nn.sharding import current_rules
        if current_rules().get("softmax_dtype") == "compute":
            p = jax.nn.softmax(sc, axis=-1)
        else:
            p = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgcs,bskh->bkgch", p, v)
        if head_shard:
            o = shard(o, ("batch", "attn_kv", None, None, None))
        return jax.lax.dynamic_update_index_in_dim(outs, o, qi, 0)

    outs0 = jnp.zeros((nq, b, kv, group, chunk_q, vd), q.dtype)
    outs = jax.lax.fori_loop(0, nq, step, outs0)
    outs = jnp.transpose(outs, (1, 0, 4, 2, 3, 5))    # (b,nq,cq,kv,g,vd)
    return outs.reshape(b, t, h, vd)


# -- KV caches ----------------------------------------------------------------

def init_cache(batch: int, n_kv: int, capacity: int, head_dim: int,
               dtype=jnp.bfloat16) -> Cache:
    return {
        "k": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),   # tokens written so far
    }


def cache_update(cache: Cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 ring: bool) -> Cache:
    """Append T_new tokens. ``ring``: wrap around (sliding-window cache)."""
    cap = cache["k"].shape[1]
    t_new = k_new.shape[1]
    pos = cache["pos"]
    if ring:
        idx = (pos + jnp.arange(t_new)) % cap
        k = cache["k"].at[:, idx].set(k_new)
        v = cache["v"].at[:, idx].set(v_new)
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    return {"k": k, "v": v, "pos": pos + t_new}


def cache_positions(cache: Cache, ring: bool) -> jnp.ndarray:
    """Absolute position of each cache slot (-1 = empty)."""
    cap = cache["k"].shape[1]
    pos = cache["pos"]
    slots = jnp.arange(cap)
    if ring:
        # slot s holds absolute position: the last `cap` tokens
        n_wraps = jnp.maximum((pos - 1 - slots) // cap, 0)
        abs_pos = slots + n_wraps * cap
        return jnp.where(abs_pos < pos, abs_pos, -1)
    return jnp.where(slots < pos, slots, -1)


# -- GQA attention block -------------------------------------------------------

class GQAAttention(Module):
    """Grouped-query attention with RoPE/M-RoPE, qk-norm, optional window."""

    def __init__(self, d_model: int, n_heads: int, n_kv: int,
                 head_dim: Optional[int] = None, qkv_bias: bool = False,
                 qk_norm: bool = False, window: Optional[int] = None,
                 rope_theta: float = 10000.0,
                 mrope_sections: Optional[Tuple[int, ...]] = None,
                 dtype=jnp.float32):
        self.d = d_model
        self.h, self.kv = n_heads, n_kv
        self.hd = head_dim or d_model // n_heads
        self.qkv_bias, self.qk_norm = qkv_bias, qk_norm
        self.window = window
        self.theta = rope_theta
        self.mrope_sections = mrope_sections
        self.dtype = dtype

    def init(self, key):
        ks = jax.random.split(key, 4)
        d, h, kv, hd = self.d, self.h, self.kv, self.hd
        p = {
            "wq": normal_init(ks[0], (d, h * hd), std=d ** -0.5, dtype=self.dtype),
            "wk": normal_init(ks[1], (d, kv * hd), std=d ** -0.5, dtype=self.dtype),
            "wv": normal_init(ks[2], (d, kv * hd), std=d ** -0.5, dtype=self.dtype),
            "wo": normal_init(ks[3], (h * hd, d), std=(h * hd) ** -0.5, dtype=self.dtype),
        }
        if self.qkv_bias:
            p["bq"] = jnp.zeros((h * hd,), self.dtype)
            p["bk"] = jnp.zeros((kv * hd,), self.dtype)
            p["bv"] = jnp.zeros((kv * hd,), self.dtype)
        if self.qk_norm:
            p["q_norm"] = jnp.ones((hd,), self.dtype)
            p["k_norm"] = jnp.ones((hd,), self.dtype)
        return p, {}

    def _qkv(self, params, x, positions):
        b, t, _ = x.shape
        q = x @ params["wq"]
        k = x @ params["wk"]
        v = x @ params["wv"]
        if self.qkv_bias:
            q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
        q = q.reshape(b, t, self.h, self.hd)
        k = k.reshape(b, t, self.kv, self.hd)
        v = v.reshape(b, t, self.kv, self.hd)
        if self.qk_norm:
            q = rms_norm(q, params["q_norm"])
            k = rms_norm(k, params["k_norm"])
        if self.mrope_sections is not None:
            assert positions.ndim == 3, "M-RoPE needs (3, B, T) positions"
            q = apply_mrope(q, positions, self.mrope_sections, self.theta)
            k = apply_mrope(k, positions, self.mrope_sections, self.theta)
        else:
            q = apply_rope(q, positions, self.theta)
            k = apply_rope(k, positions, self.theta)
        return q, k, v

    def apply(self, params, state, x, *, positions=None,
              cache: Optional[Cache] = None, impl: str = "ref", **kw):
        """Train/prefill when cache is None or being filled; decode when
        x has T=1 and cache holds history.  Returns (y, state) and the new
        cache is written into kw-out via return tuple when cache given."""
        b, t, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        q, k, v = self._qkv(params, x, positions)

        if cache is None:
            # §Perf "attn_kv": duplicate kv heads so they divide the mesh
            # axis (Megatron GQA trick: TP degree > kv heads) — only in the
            # chunked (long-seq) path where head sharding matters.
            from repro.nn.sharding import axis_size
            m = axis_size("attn_kv")
            if m > 1 and t >= 2048 and self.kv % m != 0:
                import math as _math
                dup = m // _math.gcd(self.kv, m)
                if (self.h // self.kv) % dup == 0:
                    k = jnp.repeat(k, dup, axis=2)
                    v = jnp.repeat(v, dup, axis=2)
            if self.window is not None and impl == "pallas":
                from repro.kernels import ops as kops
                y = kops.window_attn(q, k, v, self.window, impl=impl)
            elif t >= 2048:
                y = chunked_sdpa(q, k, v, self.window)
            else:
                q_pos = positions if positions.ndim == 2 else positions[0]
                mask = causal_mask(q_pos, q_pos, self.window)
                y = sdpa(q, k, v, mask, impl)
            new_cache = None
        else:
            ring = self.window is not None and cache["k"].shape[1] <= self.window
            new_cache = cache_update(cache, k, v, ring=ring)
            k_all, v_all = new_cache["k"], new_cache["v"]
            kpos = cache_positions(new_cache, ring)                  # (S,)
            q_pos = positions if positions.ndim == 2 else positions[0]
            mask = (kpos[None, None, :] >= 0) & (kpos[None, None, :]
                                                 <= q_pos[:, :, None])
            if self.window is not None:
                mask &= kpos[None, None, :] > q_pos[:, :, None] - self.window
            y = sdpa(q, k_all, v_all, mask, impl)
        y = y.reshape(b, t, self.h * self.hd) @ params["wo"]
        if new_cache is not None:
            return y, new_cache
        return y, state


# -- DeepSeek-V3 Multi-head Latent Attention ----------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


class MLAAttention(Module):
    """Multi-head latent attention (DeepSeek-V2/V3).

    Cache stores the compressed latent c_kv (kv_lora_rank) + shared rope key
    (qk_rope_dim) per token — the memory win that makes V3 decode cheap.
    Prefill/train uses the decompressed form; decode uses the absorbed form
    (q projected into latent space, attention in kv_lora_rank dims).
    """

    def __init__(self, cfg: MLAConfig, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype

    def init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 8)
        d, h = c.d_model, c.n_heads
        qk = c.qk_nope_dim + c.qk_rope_dim
        std = d ** -0.5
        p = {
            "w_dq": normal_init(ks[0], (d, c.q_lora_rank), std, self.dtype),
            "q_norm": jnp.ones((c.q_lora_rank,), self.dtype),
            "w_uq": normal_init(ks[1], (c.q_lora_rank, h * qk),
                                c.q_lora_rank ** -0.5, self.dtype),
            "w_dkv": normal_init(ks[2], (d, c.kv_lora_rank), std, self.dtype),
            "kv_norm": jnp.ones((c.kv_lora_rank,), self.dtype),
            "w_kr": normal_init(ks[3], (d, c.qk_rope_dim), std, self.dtype),
            "w_uk": normal_init(ks[4], (c.kv_lora_rank, h * c.qk_nope_dim),
                                c.kv_lora_rank ** -0.5, self.dtype),
            "w_uv": normal_init(ks[5], (c.kv_lora_rank, h * c.v_head_dim),
                                c.kv_lora_rank ** -0.5, self.dtype),
            "wo": normal_init(ks[6], (h * c.v_head_dim, d),
                              (h * c.v_head_dim) ** -0.5, self.dtype),
        }
        return p, {}

    def _latents(self, params, x, positions):
        c = self.cfg
        b, t, _ = x.shape
        cq = rms_norm(x @ params["w_dq"], params["q_norm"])
        q = (cq @ params["w_uq"]).reshape(b, t, c.n_heads,
                                          c.qk_nope_dim + c.qk_rope_dim)
        q_nope, q_rope = q[..., :c.qk_nope_dim], q[..., c.qk_nope_dim:]
        q_rope = apply_rope(q_rope, positions, c.rope_theta)
        ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"])     # (B,T,r)
        k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :],
                            positions, c.rope_theta)[:, :, 0]      # (B,T,rd)
        return q_nope, q_rope, ckv, k_rope

    def apply(self, params, state, x, *, positions=None,
              cache: Optional[Cache] = None, impl: str = "ref", **kw):
        c = self.cfg
        b, t, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        q_nope, q_rope, ckv, k_rope = self._latents(params, x, positions)

        if cache is None:
            # decompressed prefill/train path
            k_nope = (ckv @ params["w_uk"]).reshape(b, t, c.n_heads, c.qk_nope_dim)
            v = (ckv @ params["w_uv"]).reshape(b, t, c.n_heads, c.v_head_dim)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                          (b, t, c.n_heads, c.qk_rope_dim))],
                axis=-1)
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            if t >= 2048:
                y = chunked_sdpa(q, k, v)
            else:
                mask = causal_mask(positions, positions)
                y = sdpa(q, k, v, mask, impl)
            new_cache = None
        else:
            # absorbed decode path: attention in latent space.
            # §Perf "mla_latent": the latent dim r is sharded over the model
            # axis — the contraction becomes a partial-sum all-reduce of the
            # (small) scores instead of gathers of the (huge) cache.
            from repro.nn.sharding import axis_size, shard
            lat = axis_size("mla_latent") > 1
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv, (0, cache["pos"], 0)),
                "kr": jax.lax.dynamic_update_slice(
                    cache["kr"], k_rope, (0, cache["pos"], 0)),
                "pos": cache["pos"] + t,
            }
            if lat:
                new_cache["ckv"] = shard(new_cache["ckv"],
                                         ("batch", None, "mla_latent"))
                new_cache["kr"] = shard(new_cache["kr"],
                                        ("batch", None, "mla_latent"))
            w_uk = params["w_uk"].reshape(c.kv_lora_rank, c.n_heads,
                                          c.qk_nope_dim)
            q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk)
            if lat:
                q_lat = shard(q_lat, ("batch", None, None, "mla_latent"))
            scale = 1.0 / jnp.sqrt(c.qk_nope_dim + c.qk_rope_dim)
            scores = (jnp.einsum("bthr,bsr->bhts", q_lat, new_cache["ckv"])
                      + jnp.einsum("bthn,bsn->bhts", q_rope, new_cache["kr"]))
            kpos = jnp.arange(new_cache["ckv"].shape[1])
            mask = (kpos[None, None, None, :] < new_cache["pos"]) & \
                   (kpos[None, None, None, :] <= positions[:, None, :, None])
            scores = jnp.where(mask, scores * scale, -1e30)
            p_att = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
            o_lat = jnp.einsum("bhts,bsr->bthr", p_att, new_cache["ckv"])
            w_uv = params["w_uv"].reshape(c.kv_lora_rank, c.n_heads,
                                          c.v_head_dim)
            y = jnp.einsum("bthr,rhv->bthv", o_lat, w_uv)
        y = y.reshape(b, t, -1) @ params["wo"]
        if new_cache is not None:
            return y, new_cache
        return y, state


def init_mla_cache(batch: int, capacity: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> Cache:
    return {"ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32)}
