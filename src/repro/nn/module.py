"""Minimal pytree module system (flax is unavailable offline).

A :class:`Module` is a plain Python object holding *static* configuration.
Parameters and mutable state (BatchNorm running stats) live in separate
pytrees:

    params, state = module.init(key)
    y, new_state = module.apply(params, state, x, train=True)

Stateless modules return ``{}`` for state and pass it through.  Everything
is jit-friendly: ``apply`` is pure.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
State = Dict[str, Any]


class Module:
    """Base class; subclasses define ``init`` and ``apply``."""

    def init(self, key: jax.Array) -> Tuple[Params, State]:
        raise NotImplementedError

    def apply(self, params: Params, state: State, *args, **kwargs):
        raise NotImplementedError

    # convenience for stateless use
    def init_params(self, key: jax.Array) -> Params:
        return self.init(key)[0]

    def __call__(self, params: Params, state: State, *args, **kwargs):
        return self.apply(params, state, *args, **kwargs)


class Lambda(Module):
    """Wrap a pure function as a (parameterless) module."""

    def __init__(self, fn: Callable, name: str = "lambda"):
        self.fn = fn
        self.name = name

    def init(self, key):
        return {}, {}

    def apply(self, params, state, x, **kwargs):
        return self.fn(x), state


class Sequential(Module):
    """Compose modules; params/state are dicts keyed by layer name."""

    def __init__(self, layers: Sequence[Tuple[str, Module]]):
        names = [n for n, _ in layers]
        assert len(set(names)) == len(names), f"duplicate layer names: {names}"
        self.layers = list(layers)

    def init(self, key):
        params: Params = {}
        state: State = {}
        keys = jax.random.split(key, max(len(self.layers), 1))
        for (name, layer), k in zip(self.layers, keys):
            p, s = layer.init(k)
            if p:
                params[name] = p
            if s:
                state[name] = s
        return params, state

    def apply(self, params, state, x, **kwargs):
        new_state: State = {}
        for name, layer in self.layers:
            p = params.get(name, {})
            s = state.get(name, {})
            x, s2 = layer.apply(p, s, x, **kwargs)
            if s2:
                new_state[name] = s2
        return x, new_state


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))


def cast_floats(tree, dtype):
    def c(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(c, tree)


# -- initializers -------------------------------------------------------------

def kaiming(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan = fan_in if fan_in is not None else shape[0] if len(shape) <= 2 else int(
        jnp.prod(jnp.asarray(shape[1:])))
    std = (2.0 / max(fan, 1)) ** 0.5
    return jax.random.normal(key, shape, dtype) * std


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * std
