"""Mamba2 mixer (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD for train/prefill: sequence split into chunks of ``chunk``;
intra-chunk terms are matmuls (MXU-friendly — this is the paper's "duality"),
inter-chunk recurrence is a scan over chunk states.  Decode is the O(1)
recurrent update against a carried state.

Shapes follow the Mamba2 head convention:
  x: (B, T, H, P)   heads x headdim,  d_inner = H*P
  A: (H,)  dt: (B, T, H)  B/C: (B, T, N)  (single "group")
State: (B, H, P, N).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import rms_norm
from repro.nn.module import Module, normal_init


def segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum': L[..., i, j] = sum_{j<k<=i} log_a[..., k].

    Returns -inf for j > i (strictly causal decay matrix).
    log_a: (..., T) -> (..., T, T).
    """
    t = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum over (j, i]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, D: Optional[jnp.ndarray] = None,
                init_state: Optional[jnp.ndarray] = None):
    """SSD forward. Returns (y, final_state).

    x: (b, T, h, p), dt: (b, T, h) (already softplus'ed), A: (h,) (negative),
    B, C: (b, T, n).
    """
    b, T, h, p = x.shape
    n = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A                                           # (b,nc,c,h) log-decay
    dA_cs = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum

    # 1) intra-chunk (diagonal block): Y_intra = (C B^T * L) (dt x)
    L = jnp.exp(segsum(jnp.swapaxes(dA, 2, 3)))            # (b,nc,h,c,c)
    CB = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)             # (b,nc,c,c)
    att = CB[:, :, None] * L                               # (b,nc,h,c,c)
    xdt = xc * dtc[..., None]                              # (b,nc,c,h,p)
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", att, xdt)

    # 2) chunk states: S_z = sum_i exp(dA_cs[end]-dA_cs[i]) B_i (dt x)_i
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (b,nc,c,h)
    S = jnp.einsum("bzin,bzihp,bzih->bzhpn", Bc, xdt, decay_to_end)

    # 3) inter-chunk recurrence over z: H_z = exp(sum dA_z) H_{z-1} + S_z
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (b,nc,h)

    def step(carry, inp):
        s_z, g_z = inp                                     # (b,h,p,n), (b,h)
        new = carry * g_z[..., None, None] + s_z
        return new, carry                                  # emit state *before* chunk

    h0 = init_state if init_state is not None else jnp.zeros((b, h, p, n), x.dtype)
    S_t = jnp.moveaxis(S, 1, 0)                            # (nc,b,h,p,n)
    g_t = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,b,h)
    final, prev_states = jax.lax.scan(step, h0, (S_t, g_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,nc,h,p,n)

    # 4) contribution of the carried state to each position
    state_decay = jnp.exp(dA_cs)                           # (b,nc,c,h)
    y_inter = jnp.einsum("bzin,bzhpn,bzih->bzihp", Cc, prev_states, state_decay)

    y = (y_intra + y_inter).reshape(b, T, h, p)
    if D is not None:
        y = y + x * D[None, None, :, None]
    return y, final


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D: Optional[jnp.ndarray] = None):
    """Single-token recurrence. state: (b,h,p,n); x_t: (b,h,p);
    dt_t: (b,h); B_t, C_t: (b,n).  Returns (y_t, new_state)."""
    dA = jnp.exp(dt_t * A)                                 # (b,h)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", B_t, x_t, dt_t)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t)
    if D is not None:
        y = y + x_t * D[None, :, None]
    return y, new_state


class Mamba2Mixer(Module):
    """Full Mamba2 block mixer: in_proj -> causal conv -> SSD -> gated out."""

    def __init__(self, d_model: int, d_state: int = 128, expand: int = 2,
                 headdim: int = 64, conv_kernel: int = 4, chunk: int = 128,
                 dtype=jnp.float32):
        self.d = d_model
        self.n = d_state
        self.d_inner = expand * d_model
        self.p = headdim
        self.h = self.d_inner // headdim
        self.ck = conv_kernel
        self.chunk = chunk
        self.dtype = dtype
        # in_proj emits [z (gate), x, B, C, dt]
        self.d_proj = 2 * self.d_inner + 2 * d_state + self.h

    def init(self, key):
        ks = jax.random.split(key, 4)
        d = self.d
        conv_ch = self.d_inner + 2 * self.n
        p = {
            "w_in": normal_init(ks[0], (d, self.d_proj), d ** -0.5, self.dtype),
            "conv_w": normal_init(ks[1], (self.ck, conv_ch), 0.2, self.dtype),
            "conv_b": jnp.zeros((conv_ch,), self.dtype),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, self.h, dtype=self.dtype)),
            "dt_bias": jnp.log(jnp.expm1(
                jnp.linspace(1e-3, 1e-1, self.h, dtype=self.dtype))),
            "D": jnp.ones((self.h,), self.dtype),
            "norm": jnp.ones((self.d_inner,), self.dtype),
            "w_out": normal_init(ks[2], (self.d_inner, d),
                                 self.d_inner ** -0.5, self.dtype),
        }
        return p, {}

    def _split(self, proj):
        di, n, h = self.d_inner, self.n, self.h
        z = proj[..., :di]
        xBC = proj[..., di:di + di + 2 * n]
        dt = proj[..., di + di + 2 * n:]
        return z, xBC, dt

    def apply(self, params, state, u, *, cache: Optional[Dict] = None,
              impl: str = "ref", **kw):
        """u: (B,T,d). cache: {'conv': (B,ck-1,ch), 'ssm': (B,h,p,n), 'pos'}.
        Returns (y, cache') when cache is given else (y, state)."""
        b, t, _ = u.shape
        proj = u @ params["w_in"]
        z, xBC, dt = self._split(proj)
        dt = jax.nn.softplus(dt + params["dt_bias"])
        A = -jnp.exp(params["A_log"])

        if cache is None:
            # causal depthwise conv over time
            pad = jnp.zeros((b, self.ck - 1, xBC.shape[-1]), xBC.dtype)
            xpad = jnp.concatenate([pad, xBC], axis=1)
            xconv = sum(params["conv_w"][i] * xpad[:, i:i + t]
                        for i in range(self.ck))
            xBC = jax.nn.silu(xconv + params["conv_b"])
            x = xBC[..., :self.d_inner].reshape(b, t, self.h, self.p)
            B = xBC[..., self.d_inner:self.d_inner + self.n]
            C = xBC[..., self.d_inner + self.n:]
            pad_t = (-t) % self.chunk
            if pad_t:
                x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
                dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
                B = jnp.pad(B, ((0, 0), (0, pad_t), (0, 0)))
                C = jnp.pad(C, ((0, 0), (0, pad_t), (0, 0)))
            if impl == "pallas":
                from repro.kernels import ops as kops
                y, final = kops.ssd_scan(x, dt, A, B, C, chunk=self.chunk)
            else:
                y, final = ssd_chunked(x, dt, A, B, C, self.chunk,
                                       D=params["D"])
            if impl == "pallas":
                y = y + x * params["D"][None, None, :, None]
            y = y[:, :t].reshape(b, t, self.d_inner)
            new_cache = None
        elif t > 1:
            # multi-token prefill into an existing cache
            xpad = jnp.concatenate([cache["conv"], xBC], axis=1)
            xconv = sum(params["conv_w"][i] * xpad[:, i:i + t]
                        for i in range(self.ck))
            new_conv = xpad[:, -(self.ck - 1):]
            xBC2 = jax.nn.silu(xconv + params["conv_b"])
            x = xBC2[..., :self.d_inner].reshape(b, t, self.h, self.p)
            B = xBC2[..., self.d_inner:self.d_inner + self.n]
            C = xBC2[..., self.d_inner + self.n:]
            pad_t = (-t) % self.chunk
            if pad_t:
                x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
                dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
                B = jnp.pad(B, ((0, 0), (0, pad_t), (0, 0)))
                C = jnp.pad(C, ((0, 0), (0, pad_t), (0, 0)))
            y, final = ssd_chunked(x, dt, A, B, C, self.chunk, D=params["D"],
                                   init_state=cache["ssm"].astype(x.dtype))
            y = y[:, :t].reshape(b, t, self.d_inner)
            new_cache = {"conv": new_conv, "ssm": final,
                         "pos": cache["pos"] + t}
        else:
            conv_hist = jnp.concatenate([cache["conv"], xBC], axis=1)
            xconv = jnp.einsum("kc,bkc->bc", params["conv_w"], conv_hist)
            xBC1 = jax.nn.silu(xconv + params["conv_b"])[:, None]
            x = xBC1[..., :self.d_inner].reshape(b, self.h, self.p)
            B = xBC1[:, 0, self.d_inner:self.d_inner + self.n]
            C = xBC1[:, 0, self.d_inner + self.n:]
            y, new_ssm = ssd_step(cache["ssm"], x, dt[:, 0], A, B, C,
                                  D=params["D"])
            y = y.reshape(b, 1, self.d_inner)
            new_cache = {"conv": conv_hist[:, 1:], "ssm": new_ssm,
                         "pos": cache["pos"] + 1}

        y = rms_norm(y * jax.nn.silu(z), params["norm"])
        y = y @ params["w_out"]
        return (y, new_cache) if new_cache is not None else (y, state)


def init_ssm_cache(batch: int, mixer: Mamba2Mixer, dtype=jnp.float32) -> Dict:
    ch = mixer.d_inner + 2 * mixer.n
    return {"conv": jnp.zeros((batch, mixer.ck - 1, ch), dtype),
            "ssm": jnp.zeros((batch, mixer.h, mixer.p, mixer.n), dtype),
            "pos": jnp.zeros((), jnp.int32)}
