"""Logical-axis sharding hints (MaxText-style).

Models annotate tensors with *logical* axis names; the launcher installs a
mesh + rules mapping logical names to mesh axes.  Without an active mesh the
hints are no-ops, so the same model code runs on one CPU device and on the
512-chip production mesh.

Canonical logical axes:
  batch        — global batch            -> ('pod', 'data') / 'data'
  seq          — sequence                -> None (or 'data' for long-context)
  act_embed    — activation d_model      -> None
  heads        — attention heads         -> 'model'
  kv_heads     — kv heads                -> 'model'
  embed        — weight d_model (FSDP)   -> 'data'
  mlp          — FFN width               -> 'model'
  experts      — MoE experts             -> 'model'
  expert_cap   — dispatch slots          -> 'model'
  vocab        — vocabulary              -> 'model'
  layers       — stacked scan layers     -> None
  kv_seq       — KV-cache sequence       -> None
  state        — SSM state dim           -> None
  ssm_heads    — SSM heads               -> 'model'
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_ctx = threading.local()

try:                                      # JAX >= 0.6: top-level export
    from jax import shard_map as _jax_shard_map
except ImportError:                       # JAX 0.4.x: experimental module
    from jax.experimental.shard_map import shard_map as _jax_shard_map

# the replication-check kwarg was renamed check_rep -> check_vma; pick the
# spelling from the actual signature, not the import location (transition
# releases exported jax.shard_map while still spelling it check_rep)
import inspect as _inspect
_SHARD_MAP_KWARG = (
    "check_vma"
    if "check_vma" in _inspect.signature(_jax_shard_map).parameters
    else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              check_rep=None, **kwargs):
    """Version-portable ``shard_map``.

    Newer JAX exports ``jax.shard_map`` and spells the replication-check
    kwarg ``check_vma``; 0.4.x only has ``jax.experimental.shard_map`` with
    ``check_rep``.  Accepts either spelling and forwards whichever the
    installed JAX understands.
    """
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        kwargs[_SHARD_MAP_KWARG] = check
    return _jax_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


DEFAULT_RULES: Dict[str, Axis] = {
    "batch": "data",
    "seq": None,
    "act_embed": None,
    "heads": "model",
    "kv_heads": "model",
    "embed": "data",
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "layers": None,
    "kv_seq": None,
    "state": None,
    "ssm_heads": "model",
    "codebooks": None,
    # §Perf optimizations (None = baseline behaviour)
    "attn_kv": None,        # attention-local kv-head sharding (+ kv dup)
    "mla_latent": None,     # MLA: shard the compressed latent dim
}


def axis_size(logical_name: str) -> int:
    """Mesh size of the axis a logical name maps to (1 when unmapped)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    ax = current_rules().get(logical_name)
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n

MULTIPOD_RULES = dict(DEFAULT_RULES, batch=("pod", "data"))


def set_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    _ctx.mesh = mesh
    _ctx.rules = dict(rules) if rules is not None else dict(DEFAULT_RULES)


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def current_rules() -> Dict[str, Axis]:
    return getattr(_ctx, "rules", dict(DEFAULT_RULES))


@contextmanager
def mesh_context(mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
    prev_mesh, prev_rules = current_mesh(), current_rules()
    set_mesh(mesh, rules)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev_mesh, prev_rules)


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    rules: Optional[Dict[str, Axis]] = None) -> P:
    rules = rules if rules is not None else current_rules()
    used = set()
    out = []
    for name in logical_axes:
        ax = rules.get(name) if name else None
        # an axis may appear only once in a spec
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def shard(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; identity otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = logical_to_spec(logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes))
