"""Mixture-of-Experts FFN — capacity-based scatter dispatch.

Supports DeepSeek-style fine-grained experts: ``n_shared`` always-on shared
experts plus ``n_experts`` routed experts with top-k (softmax or sigmoid
gating).  Dispatch is scatter/gather based (GShard capacity semantics
without the O(T·E·C) one-hot dispatch tensor, which is memory-infeasible at
DeepSeek-V3 scale):

  1. route: top-k experts per token, position-in-expert via cumsum;
  2. scatter tokens into a (groups, E·C, d) buffer (overflow → dropped);
  3. batched expert matmuls on (groups, E, C, d) — experts shard over the
     ``experts``/model axis, groups over ``batch``/data ⇒ the all-to-all
     happens at this boundary;
  4. gather back and combine with router weights.

Aux metrics (Switch load-balance loss, router z-loss, drop fraction) are
returned for the training loop.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Module, normal_init
from repro.nn.sharding import shard


def _gated_ffn(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# -- batch-local dispatch machinery -------------------------------------------
#
# Scatter/gather with leading batch dims makes GSPMD fall back to full
# replication (measured: one DeepSeek-V3 MoE layer -> 700+ GiB/device).  The
# dispatch is batch-local by construction, so on a mesh we run it inside
# shard_map over the batch axes and GSPMD never sees the scatter.

def _route_positions(idx, cap: int, e: int, k: int):
    """idx: (b, t, k) expert choices -> (slot (b, t·k), keep (b, t, k)).

    Sort-based position-in-expert ranking: O(tk log tk) time, O(tk) memory
    (a one-hot cumsum would materialize (b, t·k, E) — infeasible at 256
    experts × 1M tokens)."""
    b, t, _ = idx.shape
    tk = t * k
    flat = idx.reshape(b, tk)
    order = jnp.argsort(flat, axis=1, stable=True)
    sorted_ids = jnp.take_along_axis(flat, order, axis=1)
    first = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_ids)
    ranks = jnp.arange(tk)[None, :] - first
    pos = jnp.zeros((b, tk), jnp.int32)
    pos = pos.at[jnp.arange(b)[:, None], order].set(ranks.astype(jnp.int32))
    keep = pos.reshape(b, t, k) < cap
    slot = jnp.where(keep, idx * cap + pos.reshape(b, t, k), e * cap)
    return slot.reshape(b, tk), keep


def _batch_axes_size():
    from repro.nn.sharding import current_mesh, current_rules
    mesh = current_mesh()
    if mesh is None:
        return None, None
    bax = current_rules().get("batch")
    if bax is None:
        return None, None
    axes = (bax,) if isinstance(bax, str) else tuple(bax)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return (bax if isinstance(bax, str) else tuple(axes)), n


def _maybe_batch_local(fn, args, n_out: int, axes_override=None):
    """Run fn inside shard_map over the batch axes when a mesh is active.

    axes_override: explicit (axis-name-or-tuple, total-size) for the group
    axis — used by the fine-grained (batch × seq-shard) grouping."""
    from jax.sharding import PartitionSpec as P
    from repro.nn.sharding import current_mesh, shard_map
    mesh = current_mesh()
    if axes_override is not None:
        bax, n = axes_override
    else:
        bax, n = _batch_axes_size()
    b = args[0].shape[0]
    if mesh is None or bax is None or b % n != 0:
        return fn(*args)
    in_specs = tuple(P(bax, *([None] * (a.ndim - 1))) for a in args)
    # fn outputs all carry batch on axis 0
    def spec_for(shape):
        return P(bax, *([None] * (len(shape) - 1)))
    out_shapes = jax.eval_shape(fn, *args)
    flat, treedef = jax.tree_util.tree_flatten(out_shapes)
    out_specs = jax.tree_util.tree_unflatten(
        treedef, [spec_for(s.shape) for s in flat])
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(*args)


def _dispatch(x, idx, cap: int, e: int, k: int, axes_override=None):
    """(x (b,t,d), idx (b,t,k)) -> (x_e (b,e,cap,d), slot (b,tk), keep)."""

    def local(x, idx):
        b, t, d = x.shape
        slot, keep = _route_positions(idx, cap, e, k)
        buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
        tok = jnp.repeat(x, k, axis=1).reshape(b, t * k, d)
        buf = buf.at[jnp.arange(b)[:, None], slot].set(tok, mode="drop")
        return buf[:, :-1].reshape(b, e, cap, d), slot, keep

    return _maybe_batch_local(local, (x, idx), 3, axes_override)


def _combine(y_e, slot, wk, axes_override=None):
    """(y_e (b,e,cap,d), slot (b,tk), wk (b,t,k)) -> y (b,t,d)."""

    def local(y_e, slot, wk):
        b, e, cap, d = y_e.shape
        t, k = wk.shape[1], wk.shape[2]
        y_flat = jnp.concatenate(
            [y_e.reshape(b, e * cap, d), jnp.zeros((b, 1, d), y_e.dtype)],
            axis=1)
        y_tok = jnp.take_along_axis(y_flat, slot[..., None], axis=1)
        y_tok = y_tok.reshape(b, t, k, d)
        return (y_tok * wk[..., None]).sum(axis=2)

    return _maybe_batch_local(local, (y_e, slot, wk), 1, axes_override)


class MoEFFN(Module):
    def __init__(self, d_model: int, d_ff: int, n_experts: int, top_k: int,
                 n_shared: int = 0, capacity_factor: float = 1.25,
                 router_scale: float = 1.0, sigmoid_gate: bool = False,
                 dtype=jnp.float32):
        self.d, self.ff = d_model, d_ff
        self.e, self.k, self.sh = n_experts, top_k, n_shared
        self.cap_f = capacity_factor
        self.router_scale = router_scale
        self.sigmoid_gate = sigmoid_gate
        self.dtype = dtype

    def init(self, key):
        ks = jax.random.split(key, 7)
        d, ff, e = self.d, self.ff, self.e
        std = d ** -0.5
        p = {
            "router": normal_init(ks[0], (d, e), std, self.dtype),
            "w_gate": normal_init(ks[1], (e, d, ff), std, self.dtype),
            "w_up": normal_init(ks[2], (e, d, ff), std, self.dtype),
            "w_down": normal_init(ks[3], (e, ff, d), ff ** -0.5, self.dtype),
        }
        if self.sh:
            p["sh_gate"] = normal_init(ks[4], (d, self.sh * ff), std, self.dtype)
            p["sh_up"] = normal_init(ks[5], (d, self.sh * ff), std, self.dtype)
            p["sh_down"] = normal_init(ks[6], (self.sh * ff, d),
                                       (self.sh * ff) ** -0.5, self.dtype)
        return p, {}

    def apply(self, params, state, x, **kw) -> Tuple[jnp.ndarray, dict]:
        b, t, d = x.shape
        xg = shard(x, ("batch", "seq", "act_embed"))
        logits = (xg @ params["router"]).astype(jnp.float32)   # (b,t,E)
        scores = (jax.nn.sigmoid(logits) if self.sigmoid_gate
                  else jax.nn.softmax(logits, axis=-1))
        wk, idx = jax.lax.top_k(scores, self.k)                # (b,t,k)
        wk = (wk / jnp.maximum(wk.sum(-1, keepdims=True), 1e-9)
              * self.router_scale).astype(x.dtype)

        # decode (t == 1): one GLOBAL token group — per-batch-row groups
        # would need capacity ≥ 1 slot per (row, expert), a 256× dispatch
        # blow-up for 1 token; tensors are tiny so the plain path is fine.
        from repro.nn.sharding import axis_size, current_rules
        axes_override = None
        n_seq = axis_size("seq")
        if t == 1 and b > 1:
            g, tg = 1, b * t
            xg_d = xg.reshape(g, tg, d)
            idx_d = idx.reshape(g, tg, self.k)
            wk_d = wk.reshape(g, tg, self.k)
        elif n_seq > 1 and t % n_seq == 0:
            # §Perf D3: sequence-parallel residual — dispatch in finer
            # (batch × seq-shard) groups so the shard_map stays fully local
            # (no per-layer all-gather of the seq-sharded activations)
            g, tg = b * n_seq, t // n_seq
            xg_d = xg.reshape(g, tg, d)
            idx_d = idx.reshape(g, tg, self.k)
            wk_d = wk.reshape(g, tg, self.k)
            bax, nb = _batch_axes_size()
            if bax is not None and b % nb == 0:
                seq_ax = current_rules().get("seq")
                baxes = (bax,) if isinstance(bax, str) else tuple(bax)
                saxes = (seq_ax,) if isinstance(seq_ax, str) else tuple(seq_ax)
                axes_override = (baxes + saxes, nb * n_seq)
        else:
            g, tg = b, t
            xg_d, idx_d, wk_d = xg, idx, wk
        cap = max(int(tg * self.k * self.cap_f / self.e), 4)
        x_e, slot, keep = _dispatch(xg_d, idx_d, cap, self.e, self.k,
                                    axes_override)
        # §Perf "expert_ep": experts sharded over BOTH mesh axes (1/chip) —
        # the batch axis must yield 'data' to the expert axis here, so the
        # all-to-all moves (tiny) tokens instead of gathering (huge) weights
        from repro.nn.sharding import current_rules
        ep_both = isinstance(current_rules().get("experts"), (tuple, list))
        e_axes = (None, "experts", "expert_cap", "act_embed") if ep_both \
            else ("batch", "experts", "expert_cap", "act_embed")
        x_e = shard(x_e, e_axes)

        h = jnp.einsum("becd,edf->becf", x_e, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", x_e, params["w_up"])
        y_e = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u,
                         params["w_down"])
        y_e = shard(y_e, e_axes)

        y = _combine(y_e, slot, wk_d, axes_override).reshape(b, t, d)
        y = shard(y, ("batch", "seq", "act_embed"))

        if self.sh:
            y = y + _gated_ffn(xg, params["sh_gate"], params["sh_up"],
                               params["sh_down"])

        me = scores.reshape(-1, self.e).mean(0)                # (E,)
        counts = jnp.zeros((self.e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
        ce = counts / (b * t)                                   # tokens/expert
        aux = {"lb_loss": self.e * jnp.sum(me * ce / self.k),
               "z_loss": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
               "dropped": 1.0 - keep.mean()}
        return y, aux
