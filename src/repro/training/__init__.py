from repro.training.train_lib import (TrainState, cross_entropy,
                                      make_train_step, lm_loss)
