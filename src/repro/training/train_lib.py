"""Training loop machinery: losses, train step factory, state container."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.optim.optimizers import (Optimizer, apply_updates,
                                    clip_by_global_norm)

IGNORE = -100


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore: int = IGNORE) -> jnp.ndarray:
    """Mean token CE; labels == ignore are masked out."""
    mask = labels != ignore
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


def lm_loss(cfg: ModelConfig, logits: jnp.ndarray, batch: Dict,
            aux: Dict) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token loss + aux terms (MoE balance, z-loss, MTP)."""
    labels = batch["labels"]
    if cfg.family == "audio":
        # logits (B,T,K,V), labels (B,K,T)
        loss = cross_entropy(logits, jnp.swapaxes(labels, 1, 2))
    else:
        loss = cross_entropy(logits, labels)
    metrics = {"ce": loss}
    total = loss
    if "lb_loss" in aux:
        total = total + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
        metrics["lb_loss"] = aux["lb_loss"]
        metrics["dropped"] = aux["dropped"]
    if "mtp_logits" in aux:
        mtp_labels = jnp.roll(labels, -1, axis=-1).at[..., -1].set(IGNORE)
        mtp = cross_entropy(aux["mtp_logits"], mtp_labels)
        total = total + 0.3 * mtp
        metrics["mtp"] = mtp
    metrics["loss"] = total
    return total, metrics


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    model_state: Any
    step: int = 0


def make_train_step(model, cfg: ModelConfig, optimizer: Optimizer,
                    clip_norm: Optional[float] = 1.0,
                    impl: str = "ref", grad_accum: int = 1) -> Callable:
    """Returns train_step(params, opt_state, model_state, batch) ->
    (params, opt_state, model_state, metrics).  jit/pjit-ready.

    grad_accum > 1 splits the global batch into that many microbatches and
    accumulates gradients with a lax.scan — live activation memory scales
    with the microbatch, letting the ≥70B train_4k configs fit HBM
    (EXPERIMENTS.md §Perf / DESIGN.md §8)."""

    def loss_fn(params, model_state, batch):
        logits, aux = model.apply(params, model_state, batch, train=True,
                                  impl=impl)
        # stateful models (BN) return state through aux["state"] convention:
        new_state = aux.pop("state", model_state) if isinstance(aux, dict) else model_state
        total, metrics = lm_loss(cfg, logits, batch, aux)
        return total, (metrics, new_state)

    def compute_grads(params, model_state, batch):
        if grad_accum <= 1:
            return jax.grad(loss_fn, has_aux=True)(params, model_state,
                                                   batch)
        # reshape every batch-leading leaf to (A, B/A, ...)
        def split(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
        micro = {}
        for k, v in batch.items():
            if k == "positions3":  # (3, B, T): batch is axis 1
                b = v.shape[1]
                micro[k] = jnp.moveaxis(
                    v.reshape(3, grad_accum, b // grad_accum, *v.shape[2:]),
                    1, 0)
            else:
                micro[k] = split(v)

        def body(carry, mb):
            grads_acc, loss_acc = carry
            g, (m, _) = jax.grad(loss_fn, has_aux=True)(params, model_state,
                                                        mb)
            grads_acc = jax.tree_util.tree_map(
                lambda a, b2: a + b2.astype(a.dtype), grads_acc, g)
            return (grads_acc, loss_acc + m["loss"]), m

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, _), ms = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        return grads, (metrics, model_state)

    def train_step(params, opt_state, model_state, batch):
        grads, (metrics, new_state) = compute_grads(params, model_state,
                                                    batch)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gn
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, new_state, metrics

    return train_step


def make_classifier_train_step(model, optimizer: Optimizer,
                               clip_norm: Optional[float] = 1.0) -> Callable:
    """Train step for the CNN zoo (images, labels)."""

    def loss_fn(params, state, x, y):
        logits, new_state = model.apply(params, state, x, train=True)
        loss = cross_entropy(logits, y)
        acc = (logits.argmax(-1) == y).mean()
        return loss, ({"loss": loss, "acc": acc}, new_state)

    def step(params, opt_state, state, x, y):
        grads, (metrics, new_state) = jax.grad(
            loss_fn, has_aux=True)(params, state, x, y)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, new_state, metrics

    return step


def evaluate_classifier(model, params, state, x, y) -> float:
    logits, _ = model.apply(params, state, x, train=False)
    return float((logits.argmax(-1) == y).mean())
