"""Synthetic datasets (offline stand-ins with *learnable structure*).

``SyntheticImages``: class-conditional images from fixed random per-class
templates + structured noise — a model that learns the templates reaches
high accuracy, an untrained one sits at chance, and quantization noise
measurably degrades it.  This preserves the paper's accuracy-exploration
dynamics without ImageNet (DESIGN.md §3).

``SyntheticTokens``: Zipf-ish Markov token streams for LM training —
a learnable bigram process so training loss actually drops.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticImages:
    n_classes: int = 10
    hw: int = 32
    channels: int = 3
    noise: float = 0.35
    seed: int = 1234

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = rng.normal(
            size=(self.n_classes, self.channels, self.hw, self.hw)
        ).astype(np.float32)

    def batch(self, batch_size: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, self.n_classes, size=batch_size)
        x = self.templates[labels]
        # structured nuisance: random shift + additive noise
        shift = rng.integers(-2, 3, size=(batch_size, 2))
        x = np.stack([np.roll(np.roll(img, s[0], axis=1), s[1], axis=2)
                      for img, s in zip(x, shift)])
        x = x + self.noise * rng.normal(size=x.shape).astype(np.float32)
        return x.astype(np.float32), labels.astype(np.int32)

    def eval_set(self, n: int, seed: int = 999):
        return self.batch(n, seed)


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    order: int = 1
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 2048)      # transition table cap
        self._v = v
        # sparse-ish bigram transition: each token prefers ~8 successors
        succ = rng.integers(0, v, size=(v, 8))
        self._succ = succ

    def batch(self, batch_size: int, seq_len: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.empty((batch_size, seq_len + 1), np.int32)
        cur = rng.integers(0, self._v, size=batch_size)
        out[:, 0] = cur
        for t in range(1, seq_len + 1):
            choice = rng.integers(0, 8, size=batch_size)
            nxt = self._succ[cur, choice]
            # occasional random jump keeps entropy non-zero
            jump = rng.random(batch_size) < 0.1
            nxt = np.where(jump, rng.integers(0, self._v, size=batch_size), nxt)
            out[:, t] = nxt
            cur = nxt
        return out


def batch_iterator(ds, batch_size: int, seq_len: Optional[int] = None,
                   start_seed: int = 0) -> Iterator:
    seed = start_seed
    while True:
        if isinstance(ds, SyntheticTokens):
            yield ds.batch(batch_size, seq_len, seed)
        else:
            yield ds.batch(batch_size, seed)
        seed += 1


def make_batch_for(cfg: ModelConfig, batch_size: int, seq_len: int,
                   seed: int = 0, kind: str = "train") -> Dict[str, np.ndarray]:
    """Concrete (host) batch for a model config — used by smoke tests and
    the quickstart examples. Training batches include next-token labels."""
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        codes = rng.integers(0, cfg.vocab,
                             size=(batch_size, cfg.n_codebooks, seq_len + 1))
        return {"codes": codes[:, :, :-1].astype(np.int32),
                "labels": codes[:, :, 1:].astype(np.int32)}
    toks = SyntheticTokens(cfg.vocab).batch(batch_size, seq_len, seed)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["vision_embeds"] = rng.normal(
            size=(batch_size, cfg.n_patches, cfg.d_model)).astype(np.float32)
        total = cfg.n_patches + seq_len
        pos = np.broadcast_to(np.arange(total), (batch_size, total))
        batch["positions3"] = np.broadcast_to(
            pos, (3, batch_size, total)).astype(np.int32)
        # labels only over the text positions; pad vision region with -100
        pad = np.full((batch_size, cfg.n_patches), -100, np.int32)
        batch["labels"] = np.concatenate([pad, batch["labels"]], axis=1)
    return batch
