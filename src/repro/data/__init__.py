from repro.data.synthetic import (SyntheticImages, SyntheticTokens,
                                  batch_iterator, make_batch_for)
