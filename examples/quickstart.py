"""Quickstart: automatically find the best partitioning point for SqueezeNet
on a two-platform embedded system (16-bit Eyeriss-like + 8-bit Simba-like,
Gigabit Ethernet) — the paper's §V-A setup in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (Constraints, Explorer, Platform, QuantSpec,
                        SystemConfig, get_link)
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.models.cnn.zoo import build_cnn

# 1. the DNN as a layer graph (ONNX-equivalent op granularity)
graph = build_cnn("squeezenet11").to_graph()
print(f"SqueezeNet v1.1: {len(graph)} nodes, "
      f"{graph.total_params/1e6:.2f}M params, "
      f"{graph.total_macs/1e9:.2f} GMACs")

# 2. the distributed system
system = SystemConfig(
    platforms=[Platform("sensor-node", EYERISS_LIKE, QuantSpec(bits=16)),
               Platform("central-unit", SIMBA_LIKE, QuantSpec(bits=8))],
    links=[get_link("gige")])

# 3. explore: filter by memory/link, evaluate HW costs, NSGA-II Pareto
explorer = Explorer(graph, system,
                    objectives=("latency", "energy", "throughput"),
                    constraints=Constraints(max_link_bytes=2_000_000))
result = explorer.run(seed=0)

print(result.summary())
print("\nPareto front:")
for ev in sorted(result.pareto, key=lambda e: e.latency_s):
    name = (result.schedule[ev.cuts[0]].name if ev.cuts[0] >= 0
            else "all-on-central-unit")
    print(f"  cut after {name:24s} lat={ev.latency_s*1e3:7.3f} ms  "
          f"E={ev.energy_j*1e3:7.3f} mJ  th={ev.throughput:8.1f}/s")
