"""Quickstart: declarative exploration with ``repro.explore``.

One :class:`ExplorationSpec` describes the whole run — model, system,
objectives, constraints, search strategy — and is JSON-round-trippable, so
the same spec that runs here can be stored in a config repo or shipped to a
fleet runner.  The setup is the paper's §V-A: SqueezeNet on a 16-bit
Eyeriss-like sensor node + 8-bit Simba-like central unit over GigE.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.explore import (Campaign, ExplorationSpec, ModelRef, PlatformSpec,
                           SystemSpec, run_spec)
from repro.core.partition import Constraints

# 1. the whole exploration as one declarative, serializable spec
spec = ExplorationSpec(
    model=ModelRef("cnn", "squeezenet11"),
    system=SystemSpec(
        platforms=(PlatformSpec("sensor-node", "eyr", bits=16),
                   PlatformSpec("central-unit", "smb", bits=8)),
        links=("gige",)),
    objectives=("latency", "energy", "throughput"),
    constraints=Constraints(max_link_bytes=2_000_000))
print("spec:", spec.to_json())
assert ExplorationSpec.from_json(spec.to_json()) == spec  # JSON round-trip

# 2. run it: schedule -> candidate filtering -> metric evaluation ->
#    search strategy -> Pareto front -> Def.-2 selection (Fig. 1)
result = run_spec(spec)
print(result.summary())

print("\nPareto front:")
for ev in sorted(result.pareto, key=lambda e: e.latency_s):
    name = (result.layer_name(ev.cuts[0]) if ev.cuts[0] >= 0
            else "all-on-central-unit")
    print(f"  cut after {name:24s} lat={ev.latency_s*1e3:7.3f} ms  "
          f"E={ev.energy_j*1e3:7.3f} mJ  th={ev.throughput:8.1f}/s")

# 3. fleet mode: fan the same spec template across the CNN zoo in one
#    Campaign (shared per-arch cost tables) and get a serializable report
fleet = Campaign(spec, models=[ModelRef("cnn", n)
                               for n in ("squeezenet11", "resnet50",
                                         "efficientnet_b0")])
report = fleet.run().report
print("\n" + report.summary())

# 4. big populations: strategy="jit_nsga2" compiles the whole NSGA-II
#    generation loop (ranking, crowding, variation, metric evaluation over
#    the precomputed cost tables) into one jax.jit program — pick it when
#    pop_size climbs into the thousands (~10x the NumPy strategy at pop
#    2048; CI's benchmarks/explorer_bench.py + compare_bench.py gate keeps
#    both paths from regressing >20% run-over-run)
import dataclasses  # noqa: E402

from repro.explore import SearchSettings  # noqa: E402

jit_spec = dataclasses.replace(
    spec, search=SearchSettings(strategy="jit_nsga2", pop_size=4096,
                                n_gen=40))
print("\njit_nsga2:", run_spec(jit_spec).summary())

# 5. scaling the jit search to very large populations — the knobs
#    (worked example: EfficientNet-B0 across a 4-node chain, 3 cuts)
#
#    * rank_block   — row-tile size of the blocked Pareto-ranking kernel
#      (repro.kernels.pareto_rank).  None auto-selects: dense ranking for
#      combined populations <= 4096, 2048-row tiles beyond, so peak memory
#      is O(pop * rank_block) instead of the dense O(pop^2) that capped
#      populations around 2k.  Set it explicitly to trade tile-loop
#      overhead against working-set size.
#    * rank_impl    — 'auto' (blocked jnp on CPU, Pallas kernels on TPU),
#      'ref', or 'pallas' to pin a branch.
#    * n_restarts   — >1 vmaps that many independently seeded searches into
#      ONE compiled program (seeds seed..seed+n-1) and merges their fronts:
#      restart diversity at roughly the cost of one larger batch.
#    * rank_devices — shards the ranking tile grid across that many local
#      devices via shard_map on multi-device hosts.
#
#    With these, pop 32768 completes on a CPU host where the dense path
#    OOMs, and accelerators stay busy at 100k+ (see
#    benchmarks/explorer_bench.py, which records jit_nsga_pop_max).
scale_spec = ExplorationSpec(
    model=ModelRef("cnn", "efficientnet_b0", {"in_hw": 64}),
    system=SystemSpec(
        platforms=(PlatformSpec("cam0", "eyr", bits=16),
                   PlatformSpec("cam1", "eyr", bits=16),
                   PlatformSpec("edge", "smb", bits=8),
                   PlatformSpec("central", "smb", bits=8)),
        links=("gige", "gige", "gige")),
    objectives=("latency", "energy"),
    search=SearchSettings(strategy="jit_nsga2", pop_size=2048, n_gen=12,
                          rank_block=512,      # force the tiled ranking
                          rank_impl="auto",
                          n_restarts=2))       # 2 seeds, one compile
print("\njit_nsga2 scaled:", run_spec(scale_spec).summary())

# 6. fleet mode at zoo scale: the same Campaign, distributed.  A sweep
#    materializes as a durable work manifest (one JSON cell per
#    model x system, states driven by atomic claim/shard files), any number
#    of worker processes -- on this host or on many hosts sharing the
#    directory -- claim cells and publish report shards, and the merge is
#    report-identical to the serial Campaign.run above (same seeds, same
#    entries, serial entry order).  Equivalent shell workflow:
#
#      python -m repro.fleet init --spec spec.json --manifest sweep.manifest
#      python -m repro.fleet run  --manifest sweep.manifest --workers 2
#
#    Fault tolerance is the point: kill a worker mid-cell (or the whole
#    host) and re-run the SAME command -- done cells are never recomputed,
#    the dead worker's claim is reclaimed automatically, and only pending
#    work executes.  `python -m repro.fleet status --manifest ...` shows
#    per-cell state; `... hosts --hosts a,b,c` prints the per-host commands
#    for a multi-host run.  Failed cells retry within a bounded budget and
#    can be merged as placeholders with --allow-failed.
import tempfile  # noqa: E402

from repro.fleet import run_fleet  # noqa: E402

with tempfile.TemporaryDirectory() as mdir:
    fleet.to_manifest(mdir)                   # the Campaign from step 3
    fleet_report = run_fleet(mdir, workers=2, verbose=True)
print("\nfleet sweep (2 workers):")
print(fleet_report.summary())

from repro.fleet import report_fingerprint  # noqa: E402

assert report_fingerprint(fleet_report) == report_fingerprint(report), \
    "fleet merge must be report-identical to the serial Campaign"
print("fleet merged report == serial campaign report (modulo wall-clock)")

# 7. keep it correct: the repo's own static analyzer.  Three rule families
#    guard the contracts everything above depends on -- RPR1xx trace-safety
#    (no Python branches/host syncs on traced values inside the jitted
#    search path), RPR2xx Pallas kernel call contracts (block/grid
#    divisibility, index_map arity, no hardcoded interpret= flags), RPR3xx
#    fleet atomicity (no plain open(...,'w') bypassing the atomic-publish
#    helpers that make the fleet runtime crash-safe).  CI gates on it; run
#    it locally before pushing:
#
#      PYTHONPATH=src python -m repro.analysis src benchmarks
#      PYTHONPATH=src python -m repro.analysis --list-rules
#      PYTHONPATH=src python -m repro.analysis src --select RPR3 --format json
#
#    Suppressions live in .analysis-baseline.json and every entry must
#    carry a written justification (see CONTRIBUTING.md).
import os  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402

repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
gate = subprocess.run(
    [sys.executable, "-m", "repro.analysis", "src", "benchmarks"],
    cwd=repo, env=env, capture_output=True, text=True)
print("\nstatic analysis gate:")
print(gate.stdout.strip())
assert gate.returncode == 0, gate.stdout + gate.stderr

# 8. serve the partition for real: repro.serve.  Everything above picks
#    cuts from *models* of latency/energy; the serving runtime executes
#    them — continuous batching over partitioned LM stages with per-slot
#    admission/eviction (no lockstep waves), thread-per-stage async
#    workers that overlap emulated link wire time with compute (Def. 4:
#    steady-state throughput ~ 1/max(stage, link)), and a
#    least-outstanding-slots router over N replicas.  The walkthrough:
#    pick a cut with explore_graph on the reduced LM's graph, snap it to
#    a decoder-block boundary with lm_block_cuts, launch 2 async
#    replicas, read the merged TTFT/throughput report.  (CI's
#    benchmarks/serve_smoke.py asserts byte-identical greedy tokens vs
#    the monolithic engine; benchmarks/serve_bench.py gates the
#    async-vs-serial speedup and the Def.-4 prediction gap.)
import jax  # noqa: E402

from repro.core import Platform, QuantSpec, SystemConfig, get_link  # noqa: E402
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE  # noqa: E402
from repro.explore import explore_graph, lm_block_cuts  # noqa: E402
from repro.models.registry import build_model, get_config  # noqa: E402
from repro.serve import (PipelineServeEngine, ReplicaRouter,  # noqa: E402
                         ServeLink, poisson_traffic)
from repro.serving.pipeline import PartitionedLMRunner  # noqa: E402

lm_cfg = get_config("smollm-360m").reduced()
lm = build_model(lm_cfg)
lm_params, _ = lm.init(jax.random.PRNGKey(0))

lm_system = SystemConfig(
    [Platform("A", EYERISS_LIKE, QuantSpec(bits=16)),
     Platform("B", SIMBA_LIKE, QuantSpec(bits=8))],
    [get_link("eth10")])                       # embedded 10 Mbit/s Ethernet
lm_result = explore_graph(lm.to_graph(8), lm_system,
                          objectives=("latency", "energy", "throughput"))
sel = lm_result.selected.cuts if lm_result.selected is not None else (1,)
cuts = lm_block_cuts(sel, lm_cfg.n_layers)     # schedule cut -> block cut
print(f"\nserve: explorer cuts {tuple(sel)} -> block cuts {cuts}")

lm_runner = PartitionedLMRunner(lm, lm_params, cuts=cuts)
replicas = []
for i in range(2):
    eng = PipelineServeEngine(
        lm_runner, n_slots=8, n_groups=4, mode="async", capacity=32,
        links=[ServeLink(model=get_link("eth10"))
               for _ in range(lm_runner.n_stages - 1)],
        name=f"replica{i}")
    eng.warmup(prompt_len=8)
    replicas.append(eng)

traffic = poisson_traffic(8, rate_rps=200.0, vocab=lm_cfg.vocab,
                          prompt_len=8, max_new=6, seed=0)
served = ReplicaRouter(replicas).serve(list(traffic), realtime=False)
summary = served.summary()
print(f"serve: {served.n_done} request(s), "
      f"{summary['tokens_per_s']:.0f} tok/s over 2 replicas, "
      f"TTFT p95 {summary.get('ttft_p95_ms', 0):.0f} ms, "
      f"routed {served.extra['routed_per_replica']}")
assert served.n_done == len(traffic), "serve dropped requests"
