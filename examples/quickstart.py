"""Quickstart: declarative exploration with ``repro.explore``.

One :class:`ExplorationSpec` describes the whole run — model, system,
objectives, constraints, search strategy — and is JSON-round-trippable, so
the same spec that runs here can be stored in a config repo or shipped to a
fleet runner.  The setup is the paper's §V-A: SqueezeNet on a 16-bit
Eyeriss-like sensor node + 8-bit Simba-like central unit over GigE.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.explore import (Campaign, ExplorationSpec, ModelRef, PlatformSpec,
                           SystemSpec, run_spec)
from repro.core.partition import Constraints

# 1. the whole exploration as one declarative, serializable spec
spec = ExplorationSpec(
    model=ModelRef("cnn", "squeezenet11"),
    system=SystemSpec(
        platforms=(PlatformSpec("sensor-node", "eyr", bits=16),
                   PlatformSpec("central-unit", "smb", bits=8)),
        links=("gige",)),
    objectives=("latency", "energy", "throughput"),
    constraints=Constraints(max_link_bytes=2_000_000))
print("spec:", spec.to_json())
assert ExplorationSpec.from_json(spec.to_json()) == spec  # JSON round-trip

# 2. run it: schedule -> candidate filtering -> metric evaluation ->
#    search strategy -> Pareto front -> Def.-2 selection (Fig. 1)
result = run_spec(spec)
print(result.summary())

print("\nPareto front:")
for ev in sorted(result.pareto, key=lambda e: e.latency_s):
    name = (result.layer_name(ev.cuts[0]) if ev.cuts[0] >= 0
            else "all-on-central-unit")
    print(f"  cut after {name:24s} lat={ev.latency_s*1e3:7.3f} ms  "
          f"E={ev.energy_j*1e3:7.3f} mJ  th={ev.throughput:8.1f}/s")

# 3. fleet mode: fan the same spec template across the CNN zoo in one
#    Campaign (shared per-arch cost tables) and get a serializable report
fleet = Campaign(spec, models=[ModelRef("cnn", n)
                               for n in ("squeezenet11", "resnet50",
                                         "efficientnet_b0")])
report = fleet.run().report
print("\n" + report.summary())

# 4. big populations: strategy="jit_nsga2" compiles the whole NSGA-II
#    generation loop (ranking, crowding, variation, metric evaluation over
#    the precomputed cost tables) into one jax.jit program — pick it when
#    pop_size climbs into the thousands (~10x the NumPy strategy at pop
#    2048; CI's benchmarks/explorer_bench.py + compare_bench.py gate keeps
#    both paths from regressing >20% run-over-run)
import dataclasses  # noqa: E402

from repro.explore import SearchSettings  # noqa: E402

jit_spec = dataclasses.replace(
    spec, search=SearchSettings(strategy="jit_nsga2", pop_size=4096,
                                n_gen=40))
print("\njit_nsga2:", run_spec(jit_spec).summary())
