"""Map the paper's technique onto TPU pods: choose the pipeline stage
boundary for qwen3-14b across 2 and 4 pods connected by inter-pod DCI,
using a single :class:`Campaign` that fans one spec template across both
system sizes (per-model cost tables are built once and shared).

  PYTHONPATH=src python examples/partition_llm_pods.py
"""

from repro.explore import (Campaign, ExplorationSpec, ModelRef, PlatformSpec,
                           SystemSpec)

SEQ = 4096

# a "platform" = one pod (256 chips-worth of HBM, one chip's roofline per
# token-stream for the latency model — relative costs are what matter)
pod = PlatformSpec("pod", "tpu_v5e", bits=16,
                   mem_capacity=256 * 16 * 2 ** 30)
systems = [SystemSpec(platforms=(pod,) * n, links=("dci",) * (n - 1),
                      name=f"{n}pods")
           for n in (2, 4)]

spec = ExplorationSpec(
    model=ModelRef("registry", "qwen3-14b", {"seq": SEQ}),
    system=systems[0],
    objectives=("latency", "throughput"))

campaign = Campaign(spec, systems=systems)
result = campaign.run()

for entry in result.entries:
    res = entry.result
    if entry.system == systems[0].label:
        print(f"{spec.model.name}: {len(res.schedule)} graph nodes, "
              f"{len(res.candidates)} candidate cuts")
    s = res.selected
    names = [res.layer_name(c) for c in s.cuts]
    print(f"\n{entry.system} over dci:")
    print(f"  selected cuts: {s.cuts} ({names})")
    print(f"  stage latencies: {[f'{t*1e3:.2f}ms' for t in s.stage_latency_s]}")
    print(f"  link latencies:  {[f'{t*1e3:.2f}ms' for t in s.link_latency_s]}")
    print(f"  pipelined throughput: {s.throughput:.1f} seq/s "
          f"(vs single pod {res.baselines[0].throughput:.1f})")
    # for a homogeneous stack the Def.-2 optimum is the balanced split —
    # which is exactly what the shard_map pipeline in repro.launch.pipeline
    # assumes (stage-stacked params over the 'pod' mesh axis)

# the serializable fleet report (per-system Pareto fronts + selections)
print("\n" + result.report.summary())
