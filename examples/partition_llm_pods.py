"""Map the paper's technique onto TPU pods: choose the pipeline stage
boundary for qwen3-14b across 2 pods connected by inter-pod DCI, using the
same explorer that partitions CNNs across embedded accelerators.

  PYTHONPATH=src python examples/partition_llm_pods.py
"""

import dataclasses

from repro.core import (Explorer, Platform, QuantSpec, SystemConfig, get_link)
from repro.core.hwmodel.arch import TPU_V5E
from repro.models.registry import build_model, get_config

cfg = get_config("qwen3-14b")
model = build_model(cfg)
seq = 4096
graph = model.to_graph(seq)
print(f"{cfg.arch_id}: {len(graph)} graph nodes "
      f"({cfg.n_layers} blocks), {graph.total_params/1e9:.1f}B params")

# a "platform" = one pod (256 chips-worth of HBM, one chip's roofline per
# token-stream for the latency model — relative costs are what matter)
pod = Platform("pod", dataclasses.replace(TPU_V5E,
                                          mem_bytes=256 * 16 * 2 ** 30),
               QuantSpec(bits=16))

for n_pods, link_name in [(2, "dci"), (4, "dci")]:
    system = SystemConfig([pod] * n_pods,
                          [get_link(link_name)] * (n_pods - 1))
    ex = Explorer(graph, system, objectives=("latency", "throughput"))
    res = ex.run(seed=0)
    cuts = res.selected.cuts
    names = [graph.topo_sort()[c].name if c >= 0 else "-" for c in cuts]
    print(f"\n{n_pods} pods over {link_name}:")
    print(f"  selected cuts: {cuts} ({names})")
    print(f"  stage latencies: "
          f"{[f'{t*1e3:.2f}ms' for t in res.selected.stage_latency_s]}")
    print(f"  link latencies:  "
          f"{[f'{t*1e3:.2f}ms' for t in res.selected.link_latency_s]}")
    print(f"  pipelined throughput: {res.selected.throughput:.1f} seq/s "
          f"(vs single pod {res.baselines[0].throughput:.1f})")
    # for a homogeneous stack the Def.-2 optimum is the balanced split —
    # which is exactly what the shard_map pipeline in repro.launch.pipeline
    # assumes (stage-stacked params over the 'pod' mesh axis)
