"""End-to-end serving example (the paper-kind driver): warm-train a reduced
smollm-360m, let the exploration engine (``repro.explore``) pick the
partition, serve batched requests both monolithically and partitioned,
verify identical outputs, and report Def.-4 pipelined throughput.

This is a thin wrapper over ``repro.launch.serve`` (the real driver):

  PYTHONPATH=src python examples/serve_partitioned.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "smollm-360m", "--requests", "8",
                "--prompt-len", "32", "--max-new", "16",
                "--warm-steps", "40"]
    raise SystemExit(main())
