"""§V-C scenario: an automotive chain — sensor node (EYR), two zonal
gateways (EYR + SMB), central unit (SMB), all over Gigabit Ethernet.
NSGA-II explores multi-cut schedules; the Table-II effect appears: small
CNNs don't profit from 4 partitions, EfficientNet-B0 does.

  PYTHONPATH=src python examples/automotive_chain.py
"""

from collections import Counter

from repro.core import Explorer, Platform, QuantSpec, SystemConfig, get_link
from repro.core.hwmodel import EYERISS_LIKE, SIMBA_LIKE
from repro.models.cnn.zoo import build_cnn

system = SystemConfig(
    [Platform("sensor", EYERISS_LIKE, QuantSpec(bits=16)),
     Platform("zone-1", EYERISS_LIKE, QuantSpec(bits=16)),
     Platform("zone-2", SIMBA_LIKE, QuantSpec(bits=8)),
     Platform("central", SIMBA_LIKE, QuantSpec(bits=8))],
    [get_link("gige")] * 3)

for name in ("squeezenet11", "efficientnet_b0"):
    graph = build_cnn(name).to_graph()
    # throughput included: the §V-C discussion is throughput-driven, and
    # without it single-platform schedules dominate the 3-objective front
    # (see benchmarks/table2_multipartition.py for both objective sets)
    ex = Explorer(graph, system,
                  objectives=("latency", "energy", "bandwidth", "throughput"))
    res = ex.run(seed=0, pop_size=48, n_gen=30)
    counts = Counter(e.n_partitions for e in res.pareto)
    print(f"\n{name}: pareto front of {len(res.pareto)} schedules")
    print("  partitions used: " +
          ", ".join(f"{k}: {counts.get(k, 0)}" for k in (1, 2, 3, 4)))
    s = res.selected
    print(f"  selected {s.cuts} -> {s.n_partitions} partitions, "
          f"lat={s.latency_s*1e3:.2f} ms, E={s.energy_j*1e3:.2f} mJ, "
          f"th={s.throughput:.0f}/s")
