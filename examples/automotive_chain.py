"""§V-C scenario: an automotive chain — sensor node (EYR), two zonal
gateways (EYR + SMB), central unit (SMB), all over Gigabit Ethernet.

With the batched evaluator the full k-cut space of this 4-platform chain is
small enough to enumerate, so we use the exact ``MultiCutScan`` strategy
(NSGA-II is a one-word swap in the spec: ``strategy="nsga2"``).  The
Table-II effect appears: small CNNs don't profit from 4 partitions,
EfficientNet-B0 does.

  PYTHONPATH=src python examples/automotive_chain.py
"""

from collections import Counter

from repro.explore import (ExplorationSpec, ModelRef, PlatformSpec,
                           SearchSettings, SystemSpec, run_spec)

system = SystemSpec(
    platforms=(PlatformSpec("sensor", "eyr", bits=16),
               PlatformSpec("zone-1", "eyr", bits=16),
               PlatformSpec("zone-2", "smb", bits=8),
               PlatformSpec("central", "smb", bits=8)),
    links=("gige", "gige", "gige"))

for name in ("squeezenet11", "efficientnet_b0"):
    # throughput included: the §V-C discussion is throughput-driven, and
    # without it single-platform schedules dominate the 3-objective front
    # (see benchmarks/table2_multipartition.py for both objective sets)
    spec = ExplorationSpec(
        model=ModelRef("cnn", name),
        system=system,
        objectives=("latency", "energy", "bandwidth", "throughput"),
        search=SearchSettings(strategy="multicut"))
    res = run_spec(spec)
    counts = Counter(e.n_partitions for e in res.pareto)
    print(f"\n{name}: pareto front of {len(res.pareto)} schedules "
          f"({res.strategy} over {len(res.candidates)} candidate positions)")
    print("  partitions used: " +
          ", ".join(f"{k}: {counts.get(k, 0)}" for k in (1, 2, 3, 4)))
    s = res.selected
    print(f"  selected {s.cuts} -> {s.n_partitions} partitions, "
          f"lat={s.latency_s*1e3:.2f} ms, E={s.energy_j*1e3:.2f} mJ, "
          f"th={s.throughput:.0f}/s")
