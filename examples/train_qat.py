"""Train a reduced EfficientNet-B0 on the synthetic task, quantize it to
4 bits, measure the accuracy drop, and recover with QAT (§IV-C).

  PYTHONPATH=src python examples/train_qat.py
"""

import jax
import jax.numpy as jnp

from repro.core.quant import QuantSpec
from repro.data.synthetic import SyntheticImages, batch_iterator
from repro.models.cnn.zoo import reduced_cnn
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.quantize.evaluate import qat_finetune, quantized_eval
from repro.training.train_lib import (evaluate_classifier,
                                      make_classifier_train_step)

STEPS = 300
model = reduced_cnn("efficientnet_b0")
params, state = model.init(jax.random.PRNGKey(0))
ds = SyntheticImages(noise=0.2)
opt = adamw(warmup_cosine(2e-3, 30, STEPS))
opt_state = opt.init(params)
step = jax.jit(make_classifier_train_step(model, opt))

for i in range(STEPS):
    x, y = ds.batch(64, i)
    params, opt_state, state, metrics = step(params, opt_state, state,
                                             jnp.asarray(x), jnp.asarray(y))
    if (i + 1) % 50 == 0:
        print(f"step {i+1}: loss={float(metrics['loss']):.3f} "
              f"acc={float(metrics['acc']):.3f}")

vx, vy = ds.eval_set(512)
acc_fp = evaluate_classifier(model, params, state, jnp.asarray(vx),
                             jnp.asarray(vy))
spec = QuantSpec(bits=4)
acc_q = quantized_eval(model, params, state, vx, vy, spec)
print(f"\nfp32 accuracy:        {acc_fp:.3f}")
print(f"4-bit PTQ accuracy:   {acc_q:.3f}")

params_qat, state_qat = qat_finetune(
    model, params, state, spec, adamw(5e-4),
    batch_iterator(ds, 64, start_seed=10_000), steps=80)
acc_qat = quantized_eval(model, params_qat, state_qat, vx, vy, spec)
print(f"4-bit QAT accuracy:   {acc_qat:.3f}  "
      f"(recovered {acc_qat - acc_q:+.3f})")
